"""Megacell partitioning (section 5.1) + bundling theorem (appendix C)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.bundle import (Bundle, CostModel, exhaustive_best,
                               plan_bundles, total_cost)
from repro.core.grid import build_cell_grid, choose_grid_spec
from repro.core.partition import (Partition, compute_megacells,
                                  megacell_statics, plan_partitions)
from repro.core.types import SearchParams


def test_megacell_count_satisfies_k(rng):
    pts = rng.random((3000, 3)).astype(np.float32)
    qs = rng.random((300, 3)).astype(np.float32)
    params = SearchParams(radius=0.25, k=8)
    spec = choose_grid_spec(pts, radius=0.05, cell_size=0.05)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    st_ = megacell_statics(spec.cell_size, params, w_max=6)
    assert st_.has_megacells
    w_search, skip, rho = compute_megacells(grid, jnp.asarray(qs), st_,
                                            params)
    assert (np.asarray(w_search) >= 0).all()
    assert (np.asarray(w_search) <= st_.w_full).all()
    assert (np.asarray(rho) > 0).all()


def test_partition_grouping_is_a_permutation(rng):
    w = jnp.asarray(rng.integers(0, 4, 100), jnp.int32)
    skip = jnp.asarray(rng.integers(0, 2, 100).astype(bool))
    rho = jnp.ones((100,), jnp.float32)
    plan = plan_partitions(w, skip, rho, w_full=5)
    assert sorted(plan.perm.tolist()) == list(range(100))
    assert sum(p.count for p in plan.partitions) == 100
    # members of each partition share (w, skip)
    for p in plan.partitions:
        sel = plan.perm[p.start: p.start + p.count]
        assert (np.asarray(w)[sel] == p.w_search).all()
        assert (np.asarray(skip)[sel] == p.skip_test).all()


def _mk_parts(ns, ws):
    """Partitions with the paper's inverse N<->S correlation: sort so the
    largest query count gets the smallest window."""
    ns = sorted(ns, reverse=True)
    ws = sorted(set(ws))[: len(ns)]
    while len(ws) < len(ns):
        ws.append(ws[-1] + 1)
    parts, start = [], 0
    k = 8
    out = []
    for n, w in zip(ns, ws):
        rho = k / ((2 * w + 1) * 0.1) ** 3
        out.append(Partition(w_search=w, skip_test=False, count=n, rho=rho,
                             start=start))
        start += n
    return out


@given(st.lists(st.integers(1, 10000), min_size=1, max_size=6),
       st.lists(st.integers(1, 8), min_size=1, max_size=6))
@settings(deadline=None, max_examples=40)
def test_bundling_matches_exhaustive_under_inverse_correlation(ns, ws):
    """Appendix C theorem: the linear-scan suffix-merge strategy achieves
    the exhaustive optimum when N and S are inversely correlated."""
    parts = _mk_parts(ns, ws)
    model = CostModel()
    kw = dict(n_points=50_000, cell_size=0.1, mode="knn", k=8, w_sph=10)
    planned = plan_bundles(parts, model, **kw)
    best, best_cost = exhaustive_best(parts, model, **kw)
    got_cost = total_cost(planned, parts, model,
                          n_points=50_000, cell_size=0.1, mode="knn", k=8)
    assert got_cost <= best_cost * (1 + 1e-9), (got_cost, best_cost)


def test_bundling_disabled_is_listing3(rng):
    parts = _mk_parts([100, 50, 10], [1, 2, 3])
    model = CostModel()
    kw = dict(n_points=1000, cell_size=0.1, mode="knn", k=8, w_sph=10)
    bundles = plan_bundles(parts, model, enable=False, **kw)
    assert len(bundles) == 3
    assert all(len(b.members) == 1 for b in bundles)


def test_bundle_skip_test_conservative():
    """A merged bundle may only skip the sphere test if every member could
    AND the merged window stays sphere-inscribed."""
    parts = [
        Partition(w_search=1, skip_test=True, count=10, rho=1.0, start=0),
        Partition(w_search=4, skip_test=True, count=5, rho=1.0, start=10),
    ]
    model = CostModel(k_knn=1e12)  # force maximal merging
    bundles = plan_bundles(parts, model, n_points=100, cell_size=0.1,
                           mode="range", k=8, w_sph=2)
    merged = [b for b in bundles if len(b.members) == 2]
    for b in merged:
        assert not b.skip_test  # w=4 > w_sph=2 -> must keep the test


def test_range_cost_model_prefers_fewer_builds_when_search_cheap():
    parts = _mk_parts([1000, 900, 800], [1, 2, 3])
    model = CostModel(k_range_skip=1e-9, k_range_test=1e-9)
    bundles = plan_bundles(parts, model, n_points=10_000, cell_size=0.1,
                           mode="range", k=8, w_sph=10)
    assert len(bundles) == 1  # build cost dominates -> single bundle
