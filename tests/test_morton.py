import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.morton import morton_decode, morton_encode
from repro.core.types import GridSpec


@given(st.lists(st.tuples(st.integers(0, 1023), st.integers(0, 1023),
                          st.integers(0, 1023)), min_size=1, max_size=64))
@settings(deadline=None, max_examples=30)
def test_roundtrip(coords):
    c = jnp.asarray(coords, jnp.int32)
    dec = morton_decode(morton_encode(c))
    assert jnp.array_equal(dec, c)


def test_locality_order():
    """Morton order of a raster grid puts 2x2x2 octants contiguously."""
    coords = jnp.stack(jnp.meshgrid(*[jnp.arange(4)] * 3, indexing="ij"),
                       -1).reshape(-1, 3)
    codes = np.asarray(morton_encode(coords))
    order = np.argsort(codes)
    first8 = set(map(tuple, np.asarray(coords)[order[:8]].tolist()))
    assert first8 == {(x, y, z) for x in (0, 1) for y in (0, 1)
                      for z in (0, 1)}


def test_monotone_per_axis():
    a = morton_encode(jnp.asarray([[1, 2, 3]]))
    b = morton_encode(jnp.asarray([[1, 2, 4]]))
    assert int(a[0]) < int(b[0])


def test_spec_cell_of_clips():
    spec = GridSpec(origin=(0., 0., 0.), cell_size=0.1, dims=(4, 4, 4),
                    capacity=4)
    pos = jnp.asarray([[-1., 0.05, 99.]])
    c = spec.cell_of(pos)
    assert c.tolist() == [[0, 0, 3]]
