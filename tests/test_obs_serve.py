"""Request-scoped observability tests (DESIGN.md section 12).

The contracts:

1. **trace context** — ``trace_scope`` pins a per-thread request id,
   spans carry it (top-level ``trace`` or batch-granular ``trace_ids``),
   and ``obs.timeline(trace_id)`` reconstructs one request's spans in
   start order;
2. **per-request serve timeline** — a traced serve run yields, for every
   future, a timeline from admission through resolution with no coverage
   gaps (the ``resolve`` span's duration is the end-to-end latency, so it
   stretches back over the whole request);
3. **telemetry parity on the drain path** — spans + SLO + flight
   recording on vs off leaves the drained results bitwise-identical, the
   serve jaxpr unchanged, and the host-sync count equal (the
   ``tests/test_obs.py`` parity guarantee extended to ``serve``);
4. **SLO accounting** — declarative targets parse/validate, windowed
   attainment and burn rate compute, and the service attributes every
   terminal outcome (ok/degraded/expired/rejected/circuit_open/error) to
   its tenant;
5. **flight recorder** — breaker trips and pump crashes dump a parseable
   post-mortem JSON with events, spans, metrics, and the SLO snapshot;
6. **exporters** — ``export_openmetrics()`` conforms to the OpenMetrics
   text grammar; ``export_perfetto()`` emits valid Chrome trace_event
   JSON;
7. **reset safety** — ``obs.reset()`` runs the lifecycle hooks, so two
   back-to-back serve scenarios see clean SLO/flight state.
"""
import json
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
from repro import obs
from repro.obs import flight, slo
from repro.core import SearchParams, SimulationSession
from repro.reliability import FaultPlan, faults
from repro.serve import NeighborService, Rejected, ServeOpts

P_A = SearchParams(radius=0.11, k=8, knn_window="exact")
P_B = SearchParams(radius=0.15, k=4, knn_window="exact")

SERVE_SPAN_NAMES = {"admit", "enqueue", "drain", "stage", "launch",
                    "sync", "split", "resolve"}


@pytest.fixture(autouse=True)
def clean_obs():
    obs.reset()
    faults.configure(None)
    yield
    faults.configure(None)
    obs.configure()
    flight.configure()
    slo.configure(from_env=True)
    obs.reset()


def _assert_bitwise(got, ref):
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_array_equal(np.asarray(got.counts),
                                  np.asarray(ref.counts))
    da = np.where(np.isinf(np.asarray(got.distances2)), -1.0,
                  np.asarray(got.distances2))
    db = np.where(np.isinf(np.asarray(ref.distances2)), -1.0,
                  np.asarray(ref.distances2))
    np.testing.assert_array_equal(da, db)


# ------------------------------------------------------------ trace context


def test_trace_scope_pins_and_unpins():
    obs.configure(mode="log")
    assert obs.current_trace() is None
    with obs.trace_scope("req-a"):
        assert obs.current_trace() == "req-a"
        with obs.span("inner"):
            pass
        with obs.trace_scope("req-b"):
            assert obs.current_trace() == "req-b"
        assert obs.current_trace() == "req-a"
    assert obs.current_trace() is None
    rec = obs.recent_spans()[-1]
    assert rec["name"] == "inner" and rec["trace"] == "req-a"
    assert "t0_s" in rec and "tid" in rec


def test_explicit_trace_attr_overrides_scope():
    obs.configure(mode="log")
    with obs.trace_scope("scoped"):
        obs.record_span("a", 0.001, trace="explicit")
        with obs.span("b", trace="explicit2"):
            pass
    recs = {r["name"]: r for r in obs.recent_spans()}
    assert recs["a"]["trace"] == "explicit"
    assert recs["b"]["trace"] == "explicit2"
    # the trace attr is hoisted out of attrs, not duplicated
    assert "trace" not in (recs["a"].get("attrs") or {})


def test_timeline_matches_trace_and_trace_ids():
    obs.configure(mode="log")
    obs.record_span("admit", 0.001, t0_s=1.0, trace="req-1")
    obs.record_span("admit", 0.001, t0_s=1.5, trace="req-2")
    obs.record_span("drain", 0.002, t0_s=2.0,
                    trace_ids=["req-1", "req-2"])
    obs.record_span("resolve", 0.001, t0_s=3.0, trace="req-1")
    tl = obs.timeline("req-1")
    assert [r["name"] for r in tl] == ["admit", "drain", "resolve"]
    assert [r["t0_s"] for r in tl] == [1.0, 2.0, 3.0]
    assert [r["name"] for r in obs.timeline("req-2")] == ["admit", "drain"]
    assert obs.timeline("req-none") == []


# ------------------------------------------- per-request serve timeline


def test_serve_request_timeline_covers_admission_to_resolution(rng):
    """Acceptance: a traced serve run reconstructs, per future, a
    timeline running admission -> resolution whose span intervals form
    ONE contiguous covered range — no gaps."""
    obs.configure(mode="log")
    svc = NeighborService(ServeOpts(max_batch=512))
    svc.register_scene("s0", rng.random((900, 3)).astype(np.float32))
    futs = [svc.submit("s0", rng.random((16, 3)).astype(np.float32), P_A)
            for _ in range(4)]
    svc.drain()
    for f in futs:
        f.result(timeout=30)
        assert f.trace_id.startswith("req-")
        tl = obs.timeline(f.trace_id)
        names = [r["name"] for r in tl]
        assert names[0] == "admit"
        assert SERVE_SPAN_NAMES <= set(names)
        # coverage: sorted by start, every span begins before the union
        # of the previous spans ends (=> a single contiguous interval
        # from admission to resolution, i.e. zero gaps)
        covered_to = tl[0]["t0_s"]
        for r in tl:
            assert r["t0_s"] <= covered_to + 1e-6, \
                f"timeline gap before {r['name']}"
            covered_to = max(covered_to, r["t0_s"] + r["dur_s"])
        resolve = next(r for r in tl if r["name"] == "resolve")
        assert resolve["attrs"]["outcome"] == "ok"
        assert resolve["attrs"]["tenant"] == "s0"
        # the resolve span IS the end-to-end latency interval: it starts
        # back at admission and the covered union reaches its end
        assert resolve["t0_s"] <= tl[0]["t0_s"] + 1e-3
        assert covered_to >= resolve["t0_s"] + resolve["dur_s"] - 1e-9
    # distinct requests got distinct ids
    assert len({f.trace_id for f in futs}) == len(futs)


def test_live_session_serve_traced_parity_and_sync_attribution(rng):
    """Serving a live SimulationSession while it steps (the ROADMAP
    interleaving item), traced: every drained result is bitwise-equal to
    a quiesced ``api.query`` of the current frame, serving adds NO
    session-side host sync (one per step, exactly as unserved), and the
    spans attribute the work correctly — ``step`` spans carry no request
    trace id, while the request timeline runs admit -> resolve."""
    obs.configure(mode="log")
    pts = rng.random((400, 3)).astype(np.float32)
    sess = SimulationSession(pts, P_A)
    sess.step(pts)
    base_syncs = sess.stats()["host_syncs"]

    svc = NeighborService()
    svc.register_session("sim", sess)
    cur = pts
    n_steps = 4
    futs = []
    for _ in range(n_steps):
        cur = np.clip(cur + rng.normal(0, 0.001, cur.shape),
                      0, 1).astype(np.float32)
        sess.step(cur)
        q = rng.random((10, 3)).astype(np.float32)
        fut = svc.submit("sim", q, P_A)
        svc.drain()
        _assert_bitwise(fut.result(timeout=30),
                        api.query(sess.index, q))   # quiesced reference
        futs.append(fut)

    st = sess.stats()
    # serving added zero session-side syncs: one per step, none per query
    assert st["host_syncs"] == base_syncs + n_steps
    assert st["stats_fetches"] == 0
    # the serve side keeps its own one-sync-per-batch contract
    sst = svc.stats()
    assert sst["host_syncs"] == sst["batches"]

    spans = obs.recent_spans()
    step_spans = [r for r in spans if r["name"] == "step"]
    assert len(step_spans) >= n_steps
    # the step's wait is attributed to the step, never smeared onto a
    # request: no step span carries a request trace id
    for r in step_spans:
        assert "trace" not in r
        assert "trace_ids" not in (r.get("attrs") or {})
    for fut in futs:
        names = [r["name"] for r in obs.timeline(fut.trace_id)]
        assert names[0] == "admit" and "resolve" in names
        assert "step" not in names


# ------------------------------------- parity: full telemetry on vs off


def _run_seeded_trace(seed, n=16):
    rng = np.random.default_rng(seed)
    scenes = {f"s{i}": rng.random((700 + 100 * i, 3)).astype(np.float32)
              for i in range(2)}
    svc = NeighborService(ServeOpts(max_batch=256, max_pending=100_000))
    for sid, pts in scenes.items():
        svc.register_scene(sid, pts)
    futs = []
    for _ in range(n):
        sid = f"s{int(rng.integers(2))}"
        p = (P_A, P_B)[int(rng.integers(2))]
        q = rng.random((int(rng.integers(4, 40)), 3)).astype(np.float32)
        futs.append(svc.submit(sid, q, p))
    reports = svc.drain()
    results = [f.result(timeout=30) for f in futs]
    return results, reports, svc.stats()


def test_serve_drain_identical_with_full_telemetry_on_vs_off(rng):
    """Spans + SLO target + flight recording on vs everything off: same
    bitwise results, same batch reports, same host-sync count."""
    def run(telemetry):
        obs.reset()
        if telemetry:
            obs.configure(mode="log")
            slo.configure(slo.SLOTarget(latency_s=60.0, objective=0.99))
            flight.configure(enabled=True, path="/dev/null")
        else:
            obs.configure(mode="off")
            slo.configure(None)
            flight.configure(enabled=False)
        return _run_seeded_trace(123)

    res_off, rep_off, st_off = run(False)
    res_on, rep_on, st_on = run(True)
    assert rep_off == rep_on                     # identical drain order
    assert st_off["host_syncs"] == st_on["host_syncs"]
    assert st_off["batches"] == st_on["batches"]
    for a, b in zip(res_off, res_on):
        _assert_bitwise(a, b)
    # the on-run actually recorded: spans exist and tenants attributed
    assert any(r["name"] == "resolve" for r in obs.recent_spans())
    assert slo.BOARD.tenants() == ["s0", "s1"]


def test_serve_variant_jaxpr_identical_telemetry_on_off(rng):
    """The drain path's device program is a constant function of the
    telemetry knobs (the test_obs.py jaxpr guarantee, extended to the
    serve variant program)."""
    pts = rng.random((800, 3)).astype(np.float32)
    qs = jnp.asarray(rng.random((64, 3)).astype(np.float32))
    svc = NeighborService()
    svc.register_scene("s0", pts)
    variant = svc.registry.get("s0").variant(P_A)
    obs.configure(mode="off")
    slo.configure(None)
    flight.configure(enabled=False)
    jaxpr_off = str(jax.make_jaxpr(variant.fn)(variant.index, qs))
    obs.configure(mode="log")
    slo.configure(slo.SLOTarget())
    flight.configure(enabled=True, path="/dev/null")
    jaxpr_on = str(jax.make_jaxpr(variant.fn)(variant.index, qs))
    assert jaxpr_off == jaxpr_on


# ------------------------------------------------------------------- SLO


def test_slo_target_parse_and_validate():
    t = slo.SLOTarget.parse("latency_ms:250,objective:0.99,window_s:300")
    assert t.latency_s == pytest.approx(0.25)
    assert t.objective == 0.99 and t.window_s == 300.0
    assert t.error_budget() == pytest.approx(0.01)
    rt = slo.SLOTarget.parse(t.spec())           # spec round-trips
    assert rt.latency_s == t.latency_s and rt.objective == t.objective
    with pytest.raises(ValueError):
        slo.SLOTarget.parse("bogus:1")
    with pytest.raises(ValueError):
        slo.SLOTarget.parse("latency_ms")
    with pytest.raises(ValueError):
        slo.SLOTarget(objective=0.0)
    with pytest.raises(ValueError):
        slo.SLOTarget(latency_s=-1.0)


def test_slo_windowed_attainment_and_burn():
    board = slo.SLOBoard()
    board.configure(slo.SLOTarget(latency_s=0.1, objective=0.9,
                                  window_s=10.0))
    # 8 good + 2 bad inside the window, 5 bad outside it
    for i in range(5):
        board.record("t", "error", now=0.0)
    for i in range(8):
        board.record("t", "ok", 0.01, now=100.0)
    board.record("t", "expired", now=100.0)
    board.record("t", "ok", 5.0, now=100.0)      # over threshold -> bad
    att = board.attainment("t", now=105.0)
    assert att == pytest.approx(8 / 10)
    # burn = bad_frac / error_budget = 0.2 / 0.1
    assert board.burn_rate("t", now=105.0) == pytest.approx(2.0)
    assert board.violations(now=105.0) == {"t": (att, 0.9)}
    assert board.attainment("idle") == 1.0 and board.burn_rate("idle") == 0
    snap = board.snapshot(now=105.0)["t"]
    assert snap["requests"] == 15
    assert snap["outcomes"]["error"] == 5 and snap["outcomes"]["ok"] == 9


def test_slo_per_tenant_target_overrides_default():
    board = slo.SLOBoard()
    board.configure(slo.SLOTarget(latency_s=1.0, objective=0.5))
    board.set_target("strict", slo.SLOTarget(latency_s=0.001,
                                             objective=0.999))
    board.record("strict", "ok", 0.5, now=0.0)   # misses strict latency
    board.record("lax", "ok", 0.5, now=0.0)      # meets default latency
    assert board.attainment("strict", now=1.0) == 0.0
    assert board.attainment("lax", now=1.0) == 1.0


def test_service_attributes_every_terminal_outcome(rng):
    """ok, degraded, expired, rejected, and circuit_open all land in the
    tenant's ledger."""
    pts = rng.random((500, 3)).astype(np.float32)
    q = rng.random((8, 3)).astype(np.float32)

    # ok
    svc = NeighborService(ServeOpts(max_batch=256))
    svc.register_scene("s0", pts)
    svc.submit("s0", q, P_A)
    svc.drain()
    # expired: deadline already past at drain time
    svc.submit("s0", q, P_A, now=0.0, deadline_s=0.5)
    svc.drain(now=10.0)
    # rejected: tiny high-water mark
    tight = NeighborService(ServeOpts(max_pending=4))
    tight.register_scene("s0", pts)
    with pytest.raises(Rejected):
        tight.submit("s0", rng.random((64, 3)).astype(np.float32), P_A)
    # degraded: overload admission at the reduced ladder
    soft = NeighborService(ServeOpts(max_pending=4, degrade=True,
                                     degrade_hard=100.0, max_batch=256))
    soft.register_scene("s0", pts)
    soft.submit("s0", rng.random((64, 3)).astype(np.float32), P_A)
    soft.drain()
    # error + circuit_open: a permanently failing scene errors its first
    # batch (tripping the breaker at threshold 1), then fails fast at
    # admission with CircuitOpen
    from repro.serve import CircuitOpen
    broken = NeighborService(ServeOpts(retries=0, breaker_n=1))
    broken.register_scene("s0", pts)
    with faults.scoped(FaultPlan(launch=1.0, scene="s0")):
        f = broken.submit("s0", q, P_A)
        broken.drain()
        with pytest.raises(Exception):
            f.result()
        with pytest.raises(CircuitOpen):
            broken.submit("s0", q, P_A)

    oc = slo.snapshot()["s0"]["outcomes"]
    assert oc["ok"] >= 1
    assert oc["degraded"] >= 1
    assert oc["expired"] >= 1
    assert oc["rejected"] >= 1
    assert oc["error"] >= 1                      # the injected launch fault
    assert oc["circuit_open"] >= 1


# -------------------------------------------------------- flight recorder


def test_flight_dump_on_breaker_trip(rng, tmp_path):
    out = str(tmp_path / "flight.json")
    flight.configure(enabled=True, path=out)
    obs.configure(mode="log")
    svc = NeighborService(ServeOpts(retries=0, breaker_n=1))
    svc.register_scene("bad", rng.random((400, 3)).astype(np.float32))
    with faults.scoped(FaultPlan(launch=1.0, scene="bad")):
        fut = svc.submit("bad", rng.random((8, 3)).astype(np.float32),
                         P_A)
        svc.drain()
    with pytest.raises(Exception):
        fut.result()
    assert flight.dump_count() == 1
    doc = json.loads(open(out).read())
    assert doc["schema"] == "repro.obs/flight-v1"
    assert doc["reason"] == "breaker_open:bad"
    kinds = [e["kind"] for e in doc["events"]]
    assert "breaker_trip" in kinds and "batch_failed" in kinds
    assert doc["metrics"]["metrics"]             # registry included
    assert "bad" in doc["slo"]                   # SLO snapshot included
    assert any(s["name"] == "admit" for s in doc["spans"])


def test_flight_dump_on_pump_crash(rng, tmp_path, monkeypatch):
    out = str(tmp_path / "crash.json")
    flight.configure(enabled=True, path=out)
    svc = NeighborService()
    svc.register_scene("s0", rng.random((400, 3)).astype(np.float32))
    fut = svc.submit("s0", rng.random((8, 3)).astype(np.float32), P_A)

    def boom(*a, **k):
        raise RuntimeError("pump meltdown")

    # crash the drain loop AFTER the batch was taken off the queue, the
    # stranding hazard the containment clause exists for
    monkeypatch.setattr(svc, "_drop_dead", boom)
    with pytest.raises(RuntimeError, match="pump meltdown"):
        svc.pump(force=True)
    assert fut.done()                            # crash containment held
    doc = json.loads(open(out).read())
    assert doc["reason"] == "pump_crash"
    assert any(e["kind"] == "pump_crash" for e in doc["events"])


def test_flight_disabled_records_but_does_not_dump(tmp_path):
    flight.configure(enabled=False, path=str(tmp_path / "no.json"))
    flight.note("drain", batch=1)
    assert flight.dump("anything") is None
    assert not (tmp_path / "no.json").exists()
    assert [e["kind"] for e in flight.events()] == ["drain"]
    # an explicit path forces a dump even when disabled (debug surface)
    forced = str(tmp_path / "forced.json")
    assert flight.dump("debug", path=forced) == forced
    assert json.loads(open(forced).read())["reason"] == "debug"


# -------------------------------------------------------------- exporters

# OpenMetrics text grammar (the subset we emit): comment/TYPE lines,
# sample lines `name{labels} value`, terminated by `# EOF`.
_OM_TYPE = re.compile(r"^# TYPE [a-zA-Z_][a-zA-Z0-9_]* "
                      r"(counter|gauge|summary)$")
_OM_SAMPLE = re.compile(
    r"^[a-zA-Z_][a-zA-Z0-9_]*"
    r"(\{[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\""
    r"(,[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")*\})?"
    r" (-?\d+(\.\d+)?([eE][+-]?\d+)?|[+-]Inf|NaN)$")


def test_openmetrics_grammar_and_content(rng):
    ms = obs.metric_set("serve")
    ms.count("requests", 5)
    ms.gauge("queue_depth", 3)
    for v in (0.01, 0.02, 0.03):
        ms.observe("request_s", v)
    slo.record("tenant-a", "ok", 0.01)
    slo.record("tenant-a", "rejected")
    text = obs.export_openmetrics()
    lines = text.splitlines()
    assert lines[-1] == "# EOF" and text.endswith("\n")
    declared = set()
    for ln in lines[:-1]:
        if ln.startswith("# TYPE"):
            assert _OM_TYPE.match(ln), ln
            declared.add(ln.split()[2])
        else:
            assert _OM_SAMPLE.match(ln), ln
            fam = ln.split("{")[0].split(" ")[0]
            base = re.sub(r"_(total|sum|count)$", "", fam)
            # every sample's family was TYPE-declared first
            assert fam in declared or base in declared, ln
    # counters expose _total, histograms quantiles + _sum/_count
    assert "repro_serve_requests_total 5" in text
    assert "repro_serve_queue_depth 3" in text
    assert 'repro_serve_request_s{quantile="0.99"}' in text
    assert "repro_serve_request_s_count 3" in text
    assert 'repro_slo_attainment{tenant="tenant-a"} 0.5' in text
    assert ('repro_slo_outcomes_total{tenant="tenant-a",'
            'outcome="rejected"} 1') in text


def test_perfetto_export_trace_events(rng, tmp_path):
    obs.configure(mode="log")
    with obs.trace_scope("req-9"):
        with obs.span("admit", tenant="s0"):
            pass
    obs.record_span("drain", 0.002, trace_ids=["req-9"])
    out = str(tmp_path / "trace.json")
    assert obs.export_perfetto(out) == out
    doc = json.loads(open(out).read())
    events = doc["traceEvents"]
    assert len(events) == 2
    assert [e["ts"] for e in events] == sorted(e["ts"] for e in events)
    admit = next(e for e in events if e["name"] == "admit")
    assert admit["ph"] == "X" and admit["cat"] == "repro"
    assert admit["dur"] >= 0 and isinstance(admit["pid"], int)
    assert admit["args"]["trace"] == "req-9"
    assert admit["args"]["tenant"] == "s0"
    drain = next(e for e in events if e["name"] == "drain")
    assert drain["args"]["trace_ids"] == ["req-9"]


# ------------------------------------------------------------ reset safety


def test_reset_runs_registered_hooks():
    calls = []

    def hook():
        calls.append(1)

    obs.on_reset(hook)
    obs.reset()
    assert calls == [1]
    obs.on_reset(hook)                           # idempotent registration
    obs.reset()
    assert calls == [1, 1]                       # once per reset, not twice


def test_back_to_back_serve_scenarios_see_clean_counters(rng):
    """The regression the satellite pins: two identical serve scenarios
    separated by ``obs.reset()`` observe identical (not cumulative)
    per-tenant SLO counts and flight events."""
    def scenario():
        svc = NeighborService()
        svc.register_scene("s0",
                           rng.random((500, 3)).astype(np.float32))
        futs = [svc.submit("s0",
                           rng.random((8, 3)).astype(np.float32), P_A)
                for _ in range(3)]
        svc.drain()
        for f in futs:
            f.result(timeout=30)
        return (slo.snapshot()["s0"]["outcomes"],
                [e["kind"] for e in flight.events()])

    first_slo, first_events = scenario()
    assert first_slo["ok"] == 3 and "drain" in first_events
    obs.reset()
    assert slo.BOARD.tenants() == [] and flight.events() == []
    second_slo, second_events = scenario()
    assert second_slo == first_slo               # clean, not cumulative
    assert second_events == first_events
