"""Unified telemetry layer tests (DESIGN.md section 9).

Three contracts:

1. registry/tracing semantics — counters/gauges/histograms aggregate and
   render; spans nest, ring-buffer, and stream to JSONL;
2. the acceptance surface — with REPRO_TRACE on, one SimulationSession
   step and one ShardedSession step emit JSONL spans covering the
   plan/compile/launch/sync stages plus p50/p99 metrics, and
   ``repro.obs.summary()`` renders the unified registry;
3. the parity guarantee — the device programs and host-sync counts are
   bitwise-identical with telemetry on vs off for ``api.query``,
   ``SimulationSession.step`` and ``ShardedSession.step`` (device-side
   telemetry is computed unconditionally; only host recording is gated).
"""
import dataclasses
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import (SearchOpts, SearchParams, ShardedSession,
                        SimulationSession)
from repro.core import api, dynamic

PARAMS = SearchParams(radius=0.12, k=8, knn_window="exact")


@pytest.fixture(autouse=True)
def clean_obs():
    """Every test starts with an empty registry/ring and ends with the
    trace mode restored to whatever the environment knob says (so a
    REPRO_TRACE=1 CI run keeps its mode across this module)."""
    obs.reset()
    yield
    obs.configure()     # re-read REPRO_TRACE / REPRO_TRACE_PATH
    obs.reset()


def _read_jsonl(path):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# ---------------------------------------------------------------- registry


def test_registry_kinds_and_counters_surface():
    ms = obs.metric_set("unit")
    ms.count("steps")
    ms.count("steps", 2)
    ms.gauge("cache_entries", 7)
    for v in [0.001, 0.002, 0.003]:
        ms.observe("step_s", v)
    assert ms.counters() == {"steps": 3}       # counters only, int totals
    assert ms.counter_value("steps") == 3.0
    snap = ms.snapshot()
    assert snap["steps"]["kind"] == "counter"
    assert snap["cache_entries"]["kind"] == "gauge"
    assert snap["cache_entries"]["value"] == 7
    hist = snap["step_s"]
    assert hist["kind"] == "histogram" and hist["count"] == 3
    for key in ("p50", "p95", "p99"):
        assert key in hist


def test_histogram_percentiles_from_reservoir():
    h = obs.Histogram()
    for v in range(1, 101):
        h.observe(float(v))
    pct = h.percentiles()
    assert pct["p50"] == pytest.approx(50.5, abs=1.0)
    assert pct["p95"] == pytest.approx(95.0, abs=1.5)
    assert pct["p99"] == pytest.approx(99.0, abs=1.5)
    assert h.count == 100 and h.vmin == 1.0 and h.vmax == 100.0


def test_registry_aggregates_same_component_instances():
    """Two instances of one component (e.g. two sessions) fold into one
    aggregate row — counter totals sum."""
    a, b = obs.metric_set("session"), obs.metric_set("session")
    a.count("steps", 2)
    b.count("steps", 3)
    agg = obs.REGISTRY.aggregate()
    assert agg["session"]["steps"]["value"] == 5


def test_summary_renders_unified_table():
    ms = obs.metric_set("executor")
    ms.count("queries", 4)
    ms.observe("query_s", 0.002)
    text = obs.summary()
    assert "repro.obs summary" in text
    assert "executor" in text and "queries" in text
    # histogram rows display seconds-suffixed metrics in microseconds
    assert "query_us" in text and "p99" in text


def test_metrics_dict_schema():
    ms = obs.metric_set("exec")
    ms.count("launches", 2)
    payload = obs.metrics_dict()
    assert payload["schema"] == "repro.obs/v1"
    rows = {(r["component"], r["name"]): r for r in payload["metrics"]}
    assert rows[("exec", "launches")]["value"] == 2


# ----------------------------------------------------------------- tracing


def test_trace_knob_parsing():
    from repro.obs import tracing
    assert tracing._parse_knob(None) == ("off", None)
    assert tracing._parse_knob("0") == ("off", None)
    assert tracing._parse_knob("1") == ("log", None)
    assert tracing._parse_knob("2") == ("jsonl", None)
    assert tracing._parse_knob("jsonl") == ("jsonl", None)
    assert tracing._parse_knob("/tmp/t.jsonl") == ("jsonl", "/tmp/t.jsonl")


def test_spans_nest_and_record_paths():
    obs.configure(mode="log")
    with obs.span("step", slabs=2):
        with obs.span("plan"):
            pass
        with obs.span("launch"):
            obs.record_span("compile", 0.5)
    paths = [s["path"] for s in obs.recent_spans()]
    assert paths == ["step/plan", "step/launch/compile", "step/launch",
                     "step"]
    top = obs.recent_spans()[-1]
    assert top["attrs"] == {"slabs": 2}
    assert top["dur_s"] >= 0.0


def test_spans_dropped_when_off():
    obs.configure(mode="off")
    with obs.span("query") as sp:
        pass
    assert sp.duration >= 0.0        # timing still available to the caller
    assert obs.recent_spans() == []


def test_jsonl_streaming_and_export(tmp_path):
    out = str(tmp_path / "trace.jsonl")
    obs.configure(mode="jsonl", path=out)
    with obs.span("query", nq=64):
        pass
    ms = obs.metric_set("exec")
    ms.observe("query_s", 0.004)
    recs = _read_jsonl(out)
    assert [r["name"] for r in recs if r["type"] == "span"] == ["query"]
    # export appends the aggregated metric rows to the same stream
    obs.export_jsonl(out)
    metrics = [r for r in _read_jsonl(out) if r["type"] == "metric"]
    row = next(r for r in metrics
               if r["component"] == "exec" and r["name"] == "query_s")
    assert row["kind"] == "histogram" and "p50" in row and "p99" in row


# ------------------------------------------------- acceptance: sessions emit


def _jitter(rng, pts, scale=0.004):
    return np.clip(pts + rng.normal(0, scale, pts.shape).astype(np.float32),
                   0, 1).astype(np.float32)


def test_session_step_emits_jsonl_telemetry(rng, tmp_path):
    """One SimulationSession.step with REPRO_TRACE on emits JSONL spans
    covering plan, compile, launch, and sync, plus histogram metrics with
    p50/p99 — and the device counters ride the ONE packed host sync."""
    out = str(tmp_path / "session.jsonl")
    obs.configure(mode="jsonl", path=out)
    pts = rng.random((500, 3)).astype(np.float32)
    sess = SimulationSession(pts, PARAMS)
    sess.step(pts)                                  # cold: compiles
    sess.step(_jitter(rng, pts))                    # steady state
    obs.export_jsonl(out)

    recs = _read_jsonl(out)
    paths = {r["path"] for r in recs if r["type"] == "span"}
    assert {"step", "step/plan", "step/launch", "step/launch/compile",
            "step/sync"} <= paths
    rows = {(r["component"], r["name"]): r for r in recs
            if r["type"] == "metric"}
    hist = rows[("session", "step_s")]
    assert hist["count"] == 2 and "p50" in hist and "p99" in hist
    # device counters arrived via the packed vector: one sync per step,
    # zero separate stats fetches, occupancy histogram populated
    st = sess.stats()
    assert st["host_syncs"] == 2 and st["stats_fetches"] == 0
    assert any(k == ("session", n) for k, n in
               ((key, key[1]) for key in rows) if n.startswith("level_occ_"))
    assert "session" in obs.summary()


def test_sharded_session_step_emits_jsonl_telemetry(rng, tmp_path):
    """Same acceptance surface for the sharded step program (n_slabs=1
    runs the full shard_map path in-process on one device)."""
    out = str(tmp_path / "shard.jsonl")
    obs.configure(mode="jsonl", path=out)
    pts = rng.random((600, 3)).astype(np.float32)
    sess = ShardedSession(pts, PARAMS, n_slabs=1)
    sess.step(pts)
    sess.step(_jitter(rng, pts))
    obs.export_jsonl(out)

    recs = _read_jsonl(out)
    paths = {r["path"] for r in recs if r["type"] == "span"}
    assert {"step", "step/plan", "step/launch", "step/launch/compile",
            "step/sync"} <= paths
    rows = {(r["component"], r["name"]): r for r in recs
            if r["type"] == "metric"}
    hist = rows[("sharded_session", "step_s")]
    assert hist["count"] == 2 and "p50" in hist and "p99" in hist
    assert ("sharded_session", "halo_rows") in rows
    st = sess.stats()
    assert st["host_syncs"] == 2
    assert "sharded_session" in obs.summary()


# ----------------------------------------------- parity: telemetry on vs off


def test_query_jaxpr_identical_on_off(rng):
    """api.query traces to the same program whether host telemetry is
    recording or not (launch count included — the jaxpr is compared as a
    whole)."""
    pts = rng.random((800, 3)).astype(np.float32)
    qs = rng.random((128, 3)).astype(np.float32)
    index = api.build_index(pts, PARAMS, SearchOpts())
    obs.configure(mode="off")
    jaxpr_off = str(jax.make_jaxpr(api.query)(index, jnp.asarray(qs)))
    obs.configure(mode="log")
    jaxpr_on = str(jax.make_jaxpr(api.query)(index, jnp.asarray(qs)))
    assert jaxpr_off == jaxpr_on


def test_session_step_jaxpr_identical_on_off(rng):
    """The fused session step program is a constant function of the trace
    mode: telemetry packing is unconditional, recording is host-side."""
    pts = rng.random((400, 3)).astype(np.float32)
    sess = SimulationSession(pts, PARAMS)
    sess.step(pts)                                  # materialize the plan
    thr2 = float((sess.sopts.displacement_frac *
                  sess.index.spec.cell_size) ** 2)
    fn = functools.partial(
        dynamic._step_impl, thr2=thr2,
        margin=int(sess.sopts.reuse_margin_cells), force=False,
        self_query=True)
    args = (sess.index.grid, dataclasses.replace(sess.index, grid=None),
            sess._plan, sess.index.points, sess.index.points,
            sess.index.points)
    obs.configure(mode="off")
    jaxpr_off = str(jax.make_jaxpr(fn)(*args))
    obs.configure(mode="log")
    jaxpr_on = str(jax.make_jaxpr(fn)(*args))
    assert jaxpr_off == jaxpr_on


def test_sharded_step_jaxpr_identical_on_off(rng):
    pts = rng.random((500, 3)).astype(np.float32)
    sess = ShardedSession(pts, PARAMS, n_slabs=1)
    args = (sess._pts, sess._ids, sess._index, sess._plan,
            sess._mig_total, jnp.asarray(pts))
    prog = sess._step_fn.__wrapped__
    obs.configure(mode="off")
    jaxpr_off = str(jax.make_jaxpr(prog)(*args))
    obs.configure(mode="log")
    jaxpr_on = str(jax.make_jaxpr(prog)(*args))
    assert jaxpr_off == jaxpr_on


def test_session_results_and_syncs_identical_on_off(rng):
    """Stepping two sessions through the same trajectory, one with
    telemetry recording and one without, produces bitwise-identical
    results and identical host-sync counts."""
    pts0 = rng.random((400, 3)).astype(np.float32)
    traj = [pts0]
    for _ in range(2):
        traj.append(_jitter(rng, traj[-1]))

    def run(mode):
        obs.reset()
        obs.configure(mode=mode)
        sess = SimulationSession(pts0, PARAMS)
        outs = [sess.step(p) for p in traj]
        return outs, sess.stats()

    outs_off, st_off = run("off")
    outs_on, st_on = run("log")
    for a, b in zip(outs_off, outs_on):
        np.testing.assert_array_equal(np.asarray(a.indices),
                                      np.asarray(b.indices))
        np.testing.assert_array_equal(np.asarray(a.counts),
                                      np.asarray(b.counts))
        np.testing.assert_array_equal(np.asarray(a.distances2),
                                      np.asarray(b.distances2))
    assert st_off["host_syncs"] == st_on["host_syncs"] == len(traj)
    assert st_off["stats_fetches"] == st_on["stats_fetches"] == 0
    assert st_off["step_cache_size"] == st_on["step_cache_size"]


def test_executor_syncs_identical_on_off(rng):
    """api-level query through the executor: the one-sync contract is
    unchanged by telemetry recording."""
    from repro.core import NeighborSearch

    pts = rng.random((900, 3)).astype(np.float32)
    qs = rng.random((160, 3)).astype(np.float32)

    def run(mode):
        obs.reset()
        obs.configure(mode=mode)
        ns = NeighborSearch(pts, PARAMS, SearchOpts())
        res = ns.query(qs)
        return res, ns.executor.stats()["last"]["host_syncs"]

    res_off, syncs_off = run("off")
    res_on, syncs_on = run("log")
    assert syncs_off == syncs_on == 1
    np.testing.assert_array_equal(np.asarray(res_off.indices),
                                  np.asarray(res_on.indices))
