"""Reliability layer tests (repro.reliability + serve failure paths,
DESIGN.md section 11).

The contracts:

1. **determinism of chaos** — a seeded ``FaultPlan`` injects the same
   faults at the same decision points on every run (hash decisions, spec
   round-trip, budgets, per-scene scoping);
2. **no future ever hangs** — under a seeded chaos plan (launch failures,
   stragglers, poisoned inputs) every submitted request resolves as
   exactly one of {result, DeadlineExceeded, QueryError, Rejected,
   CircuitOpen, InjectedFault} with bitwise parity to ``api.query`` on
   every non-degraded success, and with ``REPRO_FAULTS`` unset the jaxprs
   and host-sync counts are identical to the fault-free build;
3. **failure handling** — deadlines expire queued work BEFORE launch,
   cancelled futures never launch, transient launch failures retry with
   bounded backoff, a poisoned scene's circuit breaker isolates it while
   healthy tenants keep draining, and a crashed pump fails its in-flight
   futures instead of stranding them;
4. **graceful degradation** — invalid inputs fail structured
   (``QueryError``), overload serves at a reduced ladder level flagged
   via ``ResultQuality``, and device overflow/oob counters reach the
   per-response quality flags.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
from repro import obs
from repro.core import SearchOpts, SearchParams, SimulationSession
from repro.reliability import (CircuitBreaker, CircuitOpen,
                               DeadlineExceeded, FaultPlan, InjectedFault,
                               QueryError, ResultQuality, faults,
                               is_transient)
from repro.reliability.errors import Cancelled, TransientFault
from repro.serve import MicroBatcher, NeighborService, Rejected, ServeOpts

P_A = SearchParams(radius=0.11, k=8, knn_window="exact")
P_B = SearchParams(radius=0.15, k=4, knn_window="exact")


@pytest.fixture(autouse=True)
def clean(monkeypatch):
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    monkeypatch.delenv("REPRO_VALIDATE", raising=False)
    obs.reset()
    faults.configure(None)
    yield
    faults.configure(None)
    obs.configure()
    obs.reset()


def _assert_bitwise(got, ref):
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_array_equal(np.asarray(got.counts),
                                  np.asarray(ref.counts))
    da = np.where(np.isinf(np.asarray(got.distances2)), -1.0,
                  np.asarray(got.distances2))
    db = np.where(np.isinf(np.asarray(ref.distances2)), -1.0,
                  np.asarray(ref.distances2))
    np.testing.assert_array_equal(da, db)


def _svc(rng, n=600, scene="s", **kw):
    pts = rng.random((n, 3)).astype(np.float32)
    svc = NeighborService(ServeOpts(**kw))
    svc.register_scene(scene, pts)
    return svc, pts


# ------------------------------------------------- fault-plan determinism


def test_fault_plan_deterministic_and_parse():
    spec = "launch:0.2,straggler:0.1,poison:0.05,seed:7,delay_ms:2"
    a, b = FaultPlan.parse(spec), FaultPlan.parse(spec)
    assert a.rates["launch"] == 0.2 and a.seed == 7
    assert a.delay_s == pytest.approx(0.002)
    fired_a = [a.decide("launch") for _ in range(300)]
    fired_b = [b.decide("launch") for _ in range(300)]
    assert fired_a == fired_b                       # same seeded schedule
    n_fired = sum(x is not None for x in fired_a)
    assert 20 <= n_fired <= 100                     # ~20% of 300
    # different seed -> different schedule
    c = FaultPlan(launch=0.2, seed=8)
    assert [c.decide("launch") for _ in range(300)] != fired_a
    with pytest.raises(ValueError):
        FaultPlan(launch=1.5)
    with pytest.raises(ValueError):
        FaultPlan.parse("bogus:1")


def test_fault_plan_budget_and_scene_scope():
    plan = FaultPlan(launch=1.0, budgets={"launch": 2})
    fired = [plan.decide("launch") for _ in range(10)]
    assert sum(x is not None for x in fired) == 2   # budget caps injections
    # scoped to one scene: other tenants never fire AND don't consume
    # decisions, so the victim's schedule is traffic-independent
    scoped_plan = FaultPlan(launch=1.0, scene="bad")
    assert scoped_plan.decide("launch", scene="healthy") is None
    assert scoped_plan.decide("launch", scene="bad") == 0
    assert scoped_plan.stats()["decisions"]["launch"] == 1
    rt = FaultPlan.parse(scoped_plan.spec())        # spec round-trips
    assert rt.rates == scoped_plan.rates and rt.scene == "bad"


def test_fault_hooks_noop_without_plan():
    faults.maybe_fail("launch")                     # must not raise
    assert faults.maybe_delay() == 0.0
    q = np.zeros((4, 3), np.float32)
    assert faults.maybe_poison(q) is q              # no copy, no mutation
    with faults.scoped(FaultPlan(launch=1.0)):
        with pytest.raises(InjectedFault) as ei:
            faults.maybe_fail("launch")
        assert is_transient(ei.value)
        assert isinstance(ei.value, TransientFault)
    faults.maybe_fail("launch")                     # scope restored


# ------------------------------------------ retry-after cold start (sat 2)


def test_retry_after_cold_start_floor():
    """Before any drain has completed the retry-after estimate must fall
    back to the configured floor — not 0 or NaN."""
    mb = MicroBatcher()
    floor = 0.002
    assert mb._retry_after(None, 64, floor) == floor          # no history
    assert mb._retry_after(float("nan"), 64, floor) == floor  # degenerate
    assert mb._retry_after(0.0, 64, floor) == floor
    assert mb._retry_after(-1.0, 64, floor) == floor
    assert mb._retry_after(float("inf"), 64, floor) == floor
    # with real history the estimate scales with the backlog, floored
    est = mb._retry_after(0.010, 64, floor)
    assert est == pytest.approx(0.010)              # empty queue: one batch
    assert mb._retry_after(1e-9, 64, floor) == floor


def test_rejected_carries_positive_retry_after_cold(rng):
    """A service rejecting before its FIRST drain (cold start) still hands
    back a usable positive retry-after."""
    svc, _ = _svc(rng, max_pending=10)
    with pytest.raises(Rejected) as ei:
        svc.submit("s", rng.random((40, 3)).astype(np.float32), P_A)
    assert ei.value.retry_after_s > 0
    assert np.isfinite(ei.value.retry_after_s)


# ------------------------------------------------------- input validation


def test_validate_queries_structured_errors(rng):
    clean = rng.random((16, 3)).astype(np.float32)
    assert api.validate_queries(clean) is clean
    bad = clean.copy()
    bad[3, 1] = np.nan
    bad[7] = np.inf
    with pytest.raises(QueryError) as ei:
        api.validate_queries(bad)
    assert ei.value.reasons.get("nan", 0) >= 1
    assert ei.value.reasons.get("inf", 0) >= 1
    assert 3 in ei.value.rows and 7 in ei.value.rows
    # sentinel-colliding magnitudes are out of domain (PARK_THRESHOLD)
    park = clean.copy()
    park[0, 0] = 2e29
    with pytest.raises(QueryError) as ei:
        api.validate_queries(park)
    assert ei.value.reasons == {"oob": 1}
    # explicit domain bounds
    with pytest.raises(QueryError):
        api.validate_queries(clean, lo=0.5)
    # tracers and device arrays pass through untouched
    dev = jnp.asarray(clean)
    assert api.validate_queries(dev) is dev


def test_validation_env_knob_preserves_jaxpr_and_syncs(rng, monkeypatch):
    """REPRO_VALIDATE=1 must not change traced programs: validation runs
    host-side pre-upload only, so the jaxpr is identical to the knob off
    (test_obs.py style)."""
    pts = rng.random((500, 3)).astype(np.float32)
    index = api.build_index(pts, P_A)
    qs = jnp.asarray(rng.random((64, 3)).astype(np.float32))
    monkeypatch.setenv("REPRO_VALIDATE", "0")
    jaxpr_off = str(jax.make_jaxpr(api.query)(index, qs))
    monkeypatch.setenv("REPRO_VALIDATE", "1")
    jaxpr_on = str(jax.make_jaxpr(api.query)(index, qs))
    assert jaxpr_off == jaxpr_on


def test_poisoned_submission_fails_structured_not_launched(rng):
    """An injected poison (NaN row) is caught at admission: QueryError,
    no future created, nothing launched."""
    svc, _ = _svc(rng)
    with faults.scoped(FaultPlan(poison=1.0)):
        with pytest.raises(QueryError):
            svc.submit("s", rng.random((8, 3)).astype(np.float32), P_A)
    st = svc.stats()
    assert st["query_errors"] == 1
    assert st.get("batches", 0) == 0 and svc.queue_depth() == 0


# ------------------------------------------------ deadlines + cancellation


def test_deadline_expired_dropped_before_launch(rng):
    """Satellite 1: a request whose deadline passed while queued fails
    with DeadlineExceeded at bucket drain, BEFORE any launch, and is
    counted under serve.expired."""
    svc, _ = _svc(rng)
    q = rng.random((8, 3)).astype(np.float32)
    fut = svc.submit("s", q, P_A, now=0.0, deadline_s=1.0)
    live = svc.submit("s", q, P_A, now=5.0, deadline_s=100.0)
    svc.drain(now=5.0)                              # 5.0 >= 0.0 + 1.0
    assert isinstance(fut.exception(), DeadlineExceeded)
    with pytest.raises(DeadlineExceeded):
        fut.result()
    assert live.exception() is None and live.done()
    st = svc.stats()
    assert st["expired"] == 1
    assert st["batches"] == 1                       # only the live request
    assert st["resolved"] == 1


def test_cancelled_future_never_launches(rng):
    svc, _ = _svc(rng)
    q = rng.random((8, 3)).astype(np.float32)
    fut = svc.submit("s", q, P_A)
    assert fut.cancel() and fut.cancelled()
    svc.drain()
    with pytest.raises(Cancelled):
        fut.result()
    st = svc.stats()
    assert st["cancelled"] == 1 and st.get("batches", 0) == 0
    assert not fut.cancel()                         # second cancel loses
    # resolution is single-shot: a late set_result cannot clobber
    fut.set_result(object())
    with pytest.raises(Cancelled):
        fut.result()


def test_default_deadline_from_opts(rng):
    svc, _ = _svc(rng, deadline_s=1.0)
    fut = svc.submit("s", rng.random((4, 3)).astype(np.float32), P_A,
                     now=0.0)
    svc.drain(now=10.0)
    assert isinstance(fut.exception(), DeadlineExceeded)


# -------------------------------------------------------- bounded retries


def test_transient_launch_failure_retried_to_success(rng):
    """A launch fault with budget 1 fails exactly once; the bounded retry
    re-dispatches and the request still resolves bitwise-exact."""
    svc, pts = _svc(rng, retries=2, backoff_s=1e-4)
    q = rng.random((12, 3)).astype(np.float32)
    with faults.scoped(FaultPlan(launch=1.0, budgets={"launch": 1})):
        fut = svc.submit("s", q, P_A)
        svc.drain()
    _assert_bitwise(fut.result(), api.query(api.build_index(pts, P_A), q))
    st = svc.stats()
    assert st["retries"] == 1
    assert st.get("failed_batches", 0) == 0
    assert fut.quality is not None and fut.quality.oob == 0


def test_retry_budget_exhausted_fails_fast(rng):
    svc, _ = _svc(rng, retries=1, backoff_s=1e-4)
    with faults.scoped(FaultPlan(launch=1.0)):      # every dispatch fails
        fut = svc.submit("s", rng.random((6, 3)).astype(np.float32), P_A)
        svc.drain()
    assert isinstance(fut.exception(), InjectedFault)
    st = svc.stats()
    assert st["retries"] == 1 and st["failed_batches"] == 1


# -------------------------------------------------------- circuit breaker


def test_breaker_unit_state_machine():
    br = CircuitBreaker(threshold=2, cooldown_s=10.0)
    assert br.state == "closed" and br.allow(0.0)
    assert not br.record_failure(0.0)               # 1 of 2
    assert br.record_failure(0.0)                   # trips
    assert br.state == "open"
    assert not br.allow(5.0) and not br.submit_allowed(5.0)
    assert br.retry_after(5.0) == pytest.approx(5.0)
    assert br.allow(10.5)                           # half-open probe
    assert br.state == "half_open"
    assert not br.allow(10.5)                       # one probe at a time
    br.record_failure(10.5)                         # probe fails: reopen,
    assert br.state == "open"                       # cooldown doubled
    assert not br.allow(25.0) and br.allow(31.0)
    br.record_success()                             # probe succeeds
    assert br.state == "closed" and br.allow(31.0)
    assert br.trips == 2 and br.probes == 2


def test_breaker_isolates_poisoned_scene_and_recovers(rng):
    """The acceptance scenario: one tenant's scene is poisoned (every
    launch faults); its breaker opens and it fails fast, while the healthy
    tenant keeps draining the whole time; after the fault clears a
    half-open probe closes the breaker and the scene serves again."""
    pts0 = rng.random((500, 3)).astype(np.float32)
    pts1 = rng.random((400, 3)).astype(np.float32)
    svc = NeighborService(ServeOpts(retries=0, breaker_n=2,
                                    breaker_cooldown_s=10.0))
    svc.register_scene("s0", pts0)
    svc.register_scene("s1", pts1)
    q = rng.random((8, 3)).astype(np.float32)
    ref1 = api.query(api.build_index(pts1, P_A), q)

    with faults.scoped(FaultPlan(launch=1.0, scene="s0")):
        for _ in range(2):                          # 2 failures -> trips
            bad = svc.submit("s0", q, P_A, now=0.0)
            good = svc.submit("s1", q, P_A, now=0.0)
            svc.drain(now=0.0)
            assert isinstance(bad.exception(), InjectedFault)
            _assert_bitwise(good.result(), ref1)    # healthy scene drains
        assert svc.breaker_state("s0") == "open"
        assert svc.stats()["breaker_trips"] == 1

        # open: submissions fail fast with a retry-after hint; the
        # healthy tenant is untouched
        with pytest.raises(CircuitOpen) as ei:
            svc.submit("s0", q, P_A, now=1.0)
        assert ei.value.retry_after_s > 0
        good = svc.submit("s1", q, P_A, now=1.0)
        svc.drain(now=1.0)
        _assert_bitwise(good.result(), ref1)

        # past cooldown: the half-open probe still faults -> reopens with
        # a doubled cooldown
        probe = svc.submit("s0", q, P_A, now=11.0)
        svc.drain(now=11.0)
        assert isinstance(probe.exception(), InjectedFault)
        assert svc.breaker_state("s0") == "open"
        with pytest.raises(CircuitOpen):
            svc.submit("s0", q, P_A, now=12.0)      # doubled cooldown

    # fault cleared: the next probe succeeds and the breaker closes
    probe = svc.submit("s0", q, P_A, now=32.0)
    svc.drain(now=32.0)
    _assert_bitwise(probe.result(),
                    api.query(api.build_index(pts0, P_A), q))
    assert svc.breaker_state("s0") == "closed"


def test_breaker_open_fails_queued_batch_at_drain(rng):
    """Requests admitted before the breaker opened fail fast with
    CircuitOpen at drain — not silently dropped, not launched."""
    svc, _ = _svc(rng, retries=0, breaker_n=1, breaker_cooldown_s=100.0)
    q = rng.random((4, 3)).astype(np.float32)
    with faults.scoped(FaultPlan(launch=1.0, scene="s")):
        bad = svc.submit("s", q, P_A, now=0.0)      # will trip the breaker
        queued = svc.submit("s", q, P_B, now=0.0)   # behind it, own bucket
        svc.drain(now=0.0)
    assert isinstance(bad.exception(), InjectedFault)
    assert svc.breaker_state("s") == "open"
    assert isinstance(queued.exception(), CircuitOpen)
    assert svc.stats()["circuit_open"] >= 1


# ------------------------------------------------------ pump containment


def test_sync_failure_fails_futures_not_hangs(rng, monkeypatch):
    """A non-transient failure surfacing at sync time fails the batch's
    futures — no future is stranded."""
    svc, _ = _svc(rng)
    fut = svc.submit("s", rng.random((4, 3)).astype(np.float32), P_A)

    def boom(flight, now_fn=time.monotonic):
        raise RuntimeError("device lost")

    monkeypatch.setattr(svc, "_finish", boom)
    svc.drain()
    assert isinstance(fut.exception(), RuntimeError)
    assert svc.stats()["failed_batches"] == 1


def test_pump_crash_fails_taken_requests(rng, monkeypatch):
    """An exception escaping the drain loop itself (not a batch failure)
    still fails every taken request before propagating."""
    svc, _ = _svc(rng)
    fut = svc.submit("s", rng.random((4, 3)).astype(np.float32), P_A)
    monkeypatch.setattr(
        svc, "_run_batch",
        lambda *a, **kw: (_ for _ in ()).throw(MemoryError("oom")))
    with pytest.raises(MemoryError):
        svc.drain()
    assert isinstance(fut.exception(), MemoryError)
    assert svc.stats()["pump_crashes"] == 1


def test_background_pump_survives_crash(rng):
    """A crash inside the background pump restarts the loop (counted as
    serve.pump_restarts) instead of killing the thread and hanging every
    later future."""
    svc, _ = _svc(rng, max_wait_s=0.005)
    orig = svc._batcher.take
    state = {"crashed": False}

    def flaky_take(*args, **kwargs):
        if not state["crashed"] and not svc._batcher.empty():
            state["crashed"] = True
            raise RuntimeError("transient scheduler bug")
        return orig(*args, **kwargs)

    svc._batcher.take = flaky_take
    svc.start(poll_s=0.002)
    try:
        fut = svc.submit("s", rng.random((6, 3)).astype(np.float32), P_A)
        res = fut.result(timeout=30.0)              # crash did not strand it
        assert np.asarray(res.indices).shape == (6, P_A.k)
    finally:
        svc.stop()
    assert state["crashed"]
    st = svc.stats()
    assert st["pump_restarts"] >= 1 and st["pump_crashes"] >= 1


# ------------------------------------------------- stragglers (satellite 6)


def test_straggler_monitor_wired_into_pump(rng):
    """Injected stragglers are flagged by the shared StragglerMonitor
    (serve.stragglers counter + EMA gauge), and the drain completes."""
    svc, _ = _svc(rng)
    q = rng.random((16, 3)).astype(np.float32)
    svc.registry.get("s").variant(P_A).warm(16)     # compile out of the EMA
    for _ in range(4):                              # healthy EMA baseline
        svc.submit("s", q, P_A)
        svc.drain()
    with faults.scoped(FaultPlan(straggler=1.0, delay_s=0.25)):
        fut = svc.submit("s", q, P_A)
        svc.drain()
    assert fut.done() and fut.exception() is None
    st = svc.stats()
    assert st["stragglers"] >= 1
    assert svc._straggler.ema is not None


# --------------------------------------------------- graceful degradation


def test_overload_degrades_with_quality_flag(rng):
    """Past the high-water mark with degrade on, a request is admitted at
    the reduced ladder level and its response is flagged degraded — while
    a request past the hard cap is still Rejected."""
    svc, pts = _svc(rng, max_pending=50, degrade=True, degrade_hard=2.0)
    q1 = rng.random((40, 3)).astype(np.float32)
    q2 = rng.random((40, 3)).astype(np.float32)
    f1 = svc.submit("s", q1, P_A)                   # normal admission
    f2 = svc.submit("s", q2, P_A)                   # 80 > 50: degraded
    with pytest.raises(Rejected):                   # 120 > 100: hard cap
        svc.submit("s", q1, P_A)
    assert svc.stats()["degraded_admissions"] == 1
    svc.drain()

    assert f1.quality is not None and not f1.quality.reduced_ladder
    assert f2.quality.degraded and f2.quality.reduced_ladder
    assert svc.stats()["degraded_responses"] == 1
    _assert_bitwise(f1.result(), api.query(api.build_index(pts, P_A), q1))
    # the degraded response is exactly what the reduced-ladder program
    # serves: bounded-window approximate, not garbage
    ref_deg = api.query(
        api.build_index(pts, P_A, SearchOpts(w_ladder=(1,))), q2)
    _assert_bitwise(f2.result(), ref_deg)


def test_result_quality_from_counters():
    assert ResultQuality.from_counters().exact
    rq = ResultQuality.from_counters(overflow=3, oob=1, reduced_ladder=True)
    assert rq.degraded and not rq.exact
    assert rq.overflow == 3 and rq.oob == 1 and rq.reduced_ladder
    assert "overflow" in rq.reason and "ladder" in rq.reason


def test_session_quality_counters_reach_responses(rng):
    """A session-backed scene's overflow/oob telemetry (already host-side
    from the packed step) lands on the response quality flags."""
    pts = rng.random((400, 3)).astype(np.float32)
    sess = SimulationSession(pts, P_A)
    sess.step(pts)
    svc = NeighborService()
    svc.register_session("sim", sess)
    fut = svc.submit("sim", rng.random((8, 3)).astype(np.float32), P_A)
    svc.drain()
    assert fut.quality is not None
    assert fut.quality.overflow == sess.report.overflow
    assert fut.quality.oob == sess.report.oob


# ------------------------------------- session step x drain (satellite 3)


def test_session_step_and_drain_interleave_bitwise(rng):
    """100 interleaved (step, submit, drain) iterations against the same
    registered dynamic scene: every drained result is bitwise-identical
    to api.query against the session's current frame, and nothing
    deadlocks."""
    pts = rng.random((300, 3)).astype(np.float32)
    sess = SimulationSession(pts, P_A)
    sess.step(pts)
    svc = NeighborService()
    svc.register_session("sim", sess)
    cur = pts
    for t in range(100):
        cur = np.clip(cur + rng.normal(0, 0.001, cur.shape),
                      0, 1).astype(np.float32)
        sess.step(cur)
        q = rng.random((8, 3)).astype(np.float32)
        fut = svc.submit("sim", q, P_A)
        svc.drain()
        _assert_bitwise(fut.result(timeout=30.0),
                        api.query(sess.index, q))
    assert svc.queue_depth() == 0


def test_session_step_concurrent_with_background_pump(rng):
    """Stepping the session from one thread while the background pump
    drains submissions from another neither deadlocks nor strands a
    future; a final quiesced drain still serves the current frame."""
    pts = rng.random((300, 3)).astype(np.float32)
    sess = SimulationSession(pts, P_A)
    sess.step(pts)
    svc = NeighborService(ServeOpts(max_wait_s=0.002))
    svc.register_session("sim", sess)
    stop = threading.Event()
    steps = {"n": 0}

    def stepper():
        cur = pts
        srng = np.random.default_rng(42)
        while not stop.is_set() and steps["n"] < 100:
            cur = np.clip(cur + srng.normal(0, 0.001, cur.shape),
                          0, 1).astype(np.float32)
            sess.step(cur)
            steps["n"] += 1

    th = threading.Thread(target=stepper)
    svc.start(poll_s=0.001)
    th.start()
    try:
        futs = [svc.submit("sim", rng.random((6, 3)).astype(np.float32),
                           P_A) for _ in range(30)]
        for f in futs:
            f.result(timeout=60.0)                  # nothing hangs
    finally:
        stop.set()
        th.join(timeout=60.0)
        svc.stop()
    assert not th.is_alive() and steps["n"] > 0
    q = rng.random((8, 3)).astype(np.float32)
    fut = svc.submit("sim", q, P_A)
    svc.drain()
    _assert_bitwise(fut.result(), api.query(sess.index, q))


# ------------------------------------------------------- the chaos gate


def test_chaos_trace_zero_hung_futures(rng):
    """Acceptance: under a seeded FaultPlan (20% launch failures, 10%
    stragglers, 5% poisoned queries) a multi-tenant trace completes with
    every request resolved as exactly one taxonomy outcome, zero hung
    futures, and bitwise parity on every non-degraded success."""
    scenes = {"s0": rng.random((500, 3)).astype(np.float32),
              "s1": rng.random((400, 3)).astype(np.float32)}
    svc = NeighborService(ServeOpts(retries=2, backoff_s=1e-4,
                                    breaker_n=3, max_pending=100_000))
    for sid, pts in scenes.items():
        svc.register_scene(sid, pts)
    plan = FaultPlan(launch=0.2, straggler=0.1, poison=0.05, seed=7,
                     delay_s=0.002)

    submitted = []                                  # (sid, params, q, fut)
    outcomes = {"submit_error": 0}
    with faults.scoped(plan):
        now = 0.0
        for i in range(60):
            now += 0.001
            sid = ("s0", "s1")[i % 2]
            params = (P_A, P_B)[(i // 2) % 2]
            q = rng.random((int(rng.integers(4, 24)), 3)) \
                .astype(np.float32)
            try:
                submitted.append(
                    (sid, params, q, svc.submit(sid, q, params, now=now)))
            except (QueryError, Rejected, CircuitOpen) as exc:
                outcomes[type(exc).__name__] = \
                    outcomes.get(type(exc).__name__, 0) + 1
            if i % 8 == 7:
                svc.pump(now=now, force=True)
        svc.drain(now=now)

    refs = {}
    hung = 0
    for sid, params, q, fut in submitted:
        try:
            res = fut.result(timeout=30.0)
        except TimeoutError:
            hung += 1
            continue
        except (DeadlineExceeded, QueryError, CircuitOpen,
                InjectedFault) as exc:
            outcomes[type(exc).__name__] = \
                outcomes.get(type(exc).__name__, 0) + 1
            continue
        outcomes["result"] = outcomes.get("result", 0) + 1
        if not fut.quality.reduced_ladder:           # non-degraded: parity
            key = (sid, params)
            if key not in refs:
                refs[key] = api.build_index(scenes[sid], params)
            _assert_bitwise(res, api.query(refs[key], q))

    assert hung == 0                                 # NO future ever hangs
    assert sum(outcomes.values()) - outcomes["submit_error"] == 60
    assert outcomes.get("result", 0) >= 40           # most still served
    fired = plan.stats()["fired"]
    assert fired["launch"] > 0 and fired["poison"] > 0  # chaos was real
    assert svc.queue_depth() == 0


def test_no_faults_no_behavior_change(rng):
    """With REPRO_FAULTS unset and clean inputs the serving path is
    byte-for-byte the fault-free build: one host sync per batch, no
    retries/failures/expiries, exact quality flags."""
    svc, pts = _svc(rng)
    q = rng.random((16, 3)).astype(np.float32)
    futs = [svc.submit("s", q, P_A) for _ in range(5)]
    svc.drain()
    st = svc.stats()
    assert st["host_syncs"] == st["batches"]
    for key in ("retries", "failed_batches", "expired", "cancelled",
                "query_errors", "circuit_open", "pump_crashes"):
        assert st.get(key, 0) == 0, key
    ref = api.query(api.build_index(pts, P_A), q)
    for f in futs:
        _assert_bitwise(f.result(), ref)
        assert f.quality.exact and not f.quality.reduced_ladder
