"""Per-arch smoke tests (deliverable f): reduced same-family configs run a
train step on CPU with shape + finiteness asserts; decode-vs-parallel
equivalence validates the KV-cache / recurrent-state serving paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, smoke_config
from repro.data import make_batch
from repro.models.config import get_config
from repro.models.model import (count_params, decode_step, forward_logits,
                                init_decode_cache, init_params,
                                train_forward)
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_config(get_config(arch))
    params = init_params(cfg, KEY)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=1)
    opt_state = init_opt_state(params, opt_cfg)
    batch = make_batch(cfg, 2, 32, KEY)
    batch = jax.tree.map(lambda a: a[None], batch)  # n_micro = 1
    step = jax.jit(make_train_step(cfg, opt_cfg))
    params2, opt_state2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed and stayed finite
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert l0.shape == l1.shape
    assert not np.array_equal(np.asarray(l0), np.asarray(l1))
    for leaf in jax.tree.leaves(params2):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "recurrentgemma-2b",
                                  "rwkv6-7b", "minicpm3-4b",
                                  "deepseek-v3-671b"])
def test_decode_matches_parallel_forward(arch):
    """Token-by-token decode (KV cache / recurrent state) must reproduce the
    full parallel forward logits — validates cache indexing, rope offsets,
    RG-LRU and RWKV state updates."""
    cfg = smoke_config(get_config(arch))
    params = init_params(cfg, KEY)
    s = 12
    tokens = jax.random.randint(KEY, (2, s), 0, cfg.vocab, jnp.int32)
    ref = np.asarray(forward_logits(params, tokens, cfg))
    cache = init_decode_cache(cfg, 2, s + 2, jnp.float32)
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    got = []
    for i in range(s):
        logits, cache = step(params, cache, tokens[:, i: i + 1])
        got.append(np.asarray(logits)[:, 0])
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, ref, atol=2e-3, rtol=2e-3)


def test_mtp_loss_larger_than_plain():
    """deepseek MTP adds an auxiliary loss term."""
    import dataclasses
    cfg = smoke_config(get_config("deepseek-v3-671b"))
    cfg_nomtp = dataclasses.replace(cfg, mtp=False)
    params = init_params(cfg, KEY)
    batch = make_batch(cfg, 2, 16, KEY)
    l_mtp = float(train_forward(params, batch, cfg))
    l_plain = float(train_forward(params, batch, cfg_nomtp))
    assert l_mtp > l_plain


def test_vlm_vision_tokens_excluded_from_loss():
    cfg = smoke_config(get_config("qwen2-vl-7b"))
    batch = make_batch(cfg, 2, 16, KEY)
    nv = min(cfg.n_vision_tokens, 16)
    assert (np.asarray(batch["mask"])[:, :nv] == 0).all()
    assert batch["vision_embeds"].shape == (2, nv, cfg.d_model)


def test_param_counts_full_configs():
    """Rough sanity on the published sizes (exact-config shapes)."""
    expect = {
        "deepseek-v3-671b": (550e9, 800e9),
        "grok-1-314b": (250e9, 400e9),
        "command-r-plus-104b": (90e9, 120e9),
        "qwen1.5-110b": (95e9, 125e9),
        "command-r-35b": (30e9, 42e9),
        "recurrentgemma-2b": (2e9, 4.5e9),
        "minicpm3-4b": (3e9, 6e9),
        "qwen2-vl-7b": (6e9, 9e9),
        "whisper-tiny": (25e6, 80e6),
        "rwkv6-7b": (6e9, 9e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_smaller():
    cfg = get_config("deepseek-v3-671b")
    assert cfg.active_param_count() < 0.1 * count_params(cfg)


def test_layer_pattern_expansion():
    cfg = get_config("recurrentgemma-2b")
    kinds = cfg.layer_kinds
    assert len(kinds) == 26
    assert kinds[:3] == ("rglru", "rglru", "local_attn")
    assert kinds.count("local_attn") == 8
    cfg2 = get_config("deepseek-v3-671b")
    assert cfg2.layer_kinds[:3] == ("attn_dense",) * 3
    assert cfg2.layer_kinds[3:5] == ("attn", "attn")
