"""QueryExecutor contract tests (DESIGN.md section 3): oracle equivalence
of the batched/async path, the one-sync contract, and zero-recompilation
steady state."""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NeighborSearch, SearchOpts, SearchParams
from repro.kernels.ref import brute_force_search


def _result_tuple(res):
    d2 = np.asarray(res.distances2)
    return (np.asarray(res.indices), np.where(np.isinf(d2), -1.0, d2),
            np.asarray(res.counts))


@pytest.mark.parametrize("mode", ["knn", "range"])
@pytest.mark.parametrize("schedule,partition", list(
    itertools.product([False, True], repeat=2)))
def test_executor_identical_to_host_loop(rng, mode, schedule, partition):
    """The executor is a pure re-orchestration: same launches, same math —
    results must be bit-identical to the legacy per-bundle host loop,
    including padded-bucket edge rows (397 is never a bucket multiple)."""
    pts = rng.random((1800, 3)).astype(np.float32)
    qs = rng.random((397, 3)).astype(np.float32)
    params = SearchParams(radius=0.11, k=8, mode=mode, knn_window="exact")
    kw = dict(schedule=schedule, partition=partition)
    res_old = NeighborSearch(pts, params,
                             SearchOpts(executor=False, **kw)).query(qs)
    res_new = NeighborSearch(pts, params,
                             SearchOpts(executor=True, **kw)).query(qs)
    for a, b in zip(_result_tuple(res_old), _result_tuple(res_new)):
        np.testing.assert_array_equal(a, b)


def test_executor_matches_ref_oracle(rng):
    """End-to-end against kernels/ref: distances^2 and counts exact, every
    returned index verified by distance recomputation (tie-safe)."""
    pts = rng.random((2200, 3)).astype(np.float32)
    qs = rng.random((500, 3)).astype(np.float32)
    r, k = 0.1, 8
    res = NeighborSearch(pts, SearchParams(radius=r, k=k, knn_window="exact"),
                         SearchOpts()).query(qs)
    oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(qs), r, k)
    d_ref = np.where(np.isinf(np.asarray(od)), -1.0, np.asarray(od))
    d_got = np.where(np.isinf(np.asarray(res.distances2)), -1.0,
                     np.asarray(res.distances2))
    np.testing.assert_allclose(d_got, d_ref, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(oc), np.asarray(res.counts))
    ri = np.asarray(res.indices)
    valid = ri >= 0
    recompute = np.sum((qs[:, None] - pts[np.clip(ri, 0, None)]) ** 2, -1)
    np.testing.assert_allclose(recompute[valid],
                               np.asarray(res.distances2)[valid], atol=1e-5)


def test_executor_pallas_path_matches(rng):
    pts = rng.random((1500, 3)).astype(np.float32)
    qs = rng.random((300, 3)).astype(np.float32)
    params = SearchParams(radius=0.1, k=8, knn_window="exact")
    res_j = NeighborSearch(pts, params, SearchOpts()).query(qs)
    ns_p = NeighborSearch(pts, params,
                          SearchOpts(use_pallas=True, query_tile=128))
    res_p = ns_p.query(qs)
    np.testing.assert_allclose(
        np.where(np.isinf(np.asarray(res_j.distances2)), -1,
                 np.asarray(res_j.distances2)),
        np.where(np.isinf(np.asarray(res_p.distances2)), -1,
                 np.asarray(res_p.distances2)), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(res_j.counts),
                                  np.asarray(res_p.counts))
    # the pallas plan fetch carries the query cells in the same transfer
    assert ns_p.executor.stats()["last"]["host_syncs"] == 1


def test_one_sync_contract(rng):
    """Exactly one blocking result sync per query(); partitioning adds at
    most one small plan-metadata fetch (the host launch orchestration)."""
    pts = rng.random((2000, 3)).astype(np.float32)
    qs = rng.random((400, 3)).astype(np.float32)
    ns = NeighborSearch(pts, SearchParams(radius=0.09, k=8), SearchOpts())
    ns.query(qs)
    last = ns.executor.stats()["last"]
    assert last["host_syncs"] == 1
    assert last["plan_fetches"] <= 1
    assert ns.report.host_syncs == 1
    # without partitioning there is no data-dependent plan: zero fetches
    ns2 = NeighborSearch(pts, SearchParams(radius=0.09, k=8),
                         SearchOpts(partition=False))
    ns2.query(qs)
    last2 = ns2.executor.stats()["last"]
    assert last2["host_syncs"] == 1
    assert last2["plan_fetches"] == 0


def test_execute_async_overlap_matches_execute(rng):
    """Dispatch-then-stage carryover: two batches dispatched before either
    syncs return results identical to the blocking path, each paying its
    own single host sync at wait()."""
    pts = rng.random((1800, 3)).astype(np.float32)
    qa = rng.random((384, 3)).astype(np.float32)
    qb = rng.random((384, 3)).astype(np.float32)
    ns = NeighborSearch(pts, SearchParams(radius=0.09, k=8), SearchOpts())
    ref_a, ref_b = ns.query(qa), ns.query(qb)

    pa = ns.executor.execute_async(qa)      # both in flight before any sync
    pb = ns.executor.execute_async(qb)
    got_b = pb.wait()                       # out-of-order sync is fine
    got_a = pa.wait()
    for got, ref in ((got_a, ref_a), (got_b, ref_b)):
        np.testing.assert_array_equal(np.asarray(got.indices),
                                      np.asarray(ref.indices))
        np.testing.assert_array_equal(np.asarray(got.counts),
                                      np.asarray(ref.counts))
    last = ns.executor.stats()["last"]
    assert last["host_syncs"] == 1          # per-batch, not accumulated
    assert last["plan_cache_hit"] and last["launcher_cache_hit"]
    assert pa.wait() is got_a               # idempotent
    assert pa.done() and pb.done()


def test_signature_batching_folds_bundles(rng):
    """Bundles sharing (w_search, skip_test) must fold into one launch:
    launches <= bundles always, and == unique signatures."""
    pts = np.concatenate([
        rng.random((3000, 3)) * 0.25,                    # dense cluster
        rng.random((300, 3)) * 0.75 + 0.25,              # sparse remainder
    ]).astype(np.float32)
    qs = pts[rng.integers(0, len(pts), 500)]
    ns = NeighborSearch(pts, SearchParams(radius=0.08, k=16, mode="range"),
                        SearchOpts(bundle=False))   # 1 bundle per partition
    ns.query(qs)
    sigs = {(b.w_search, b.skip_test) for b in ns.report.bundles}
    assert ns.report.launches == len(sigs)
    assert ns.report.launches <= len(ns.report.bundles)


def test_second_query_zero_recompiles(rng):
    """Steady state (SPH stepping): a repeat same-shape query must hit the
    plan cache and compile nothing."""
    from repro.core.search import window_search

    pts = rng.random((2000, 3)).astype(np.float32)
    qs = rng.random((384, 3)).astype(np.float32)
    ns = NeighborSearch(pts, SearchParams(radius=0.1, k=8), SearchOpts())
    ns.executor.warmup(qs)
    jit_before = window_search._cache_size()
    ns.query(qs)
    st = ns.executor.stats()
    assert st["last"]["compilations"] == 0
    assert st["last"]["plan_cache_hit"]
    assert window_search._cache_size() == jit_before
    # same-shape but different values: plan may differ, compiles must not
    # (padded-N bucketing bounds the signature set)
    qs2 = rng.random((384, 3)).astype(np.float32)
    jit_before = window_search._cache_size()
    ns.query(qs2)
    assert window_search._cache_size() == jit_before


def test_drifting_queries_reuse_compiled_schedule(rng):
    """The SPH regime: query values drift step to step, partition counts
    shift within the same padded buckets — the compiled launch schedule
    must be reused (launcher cache keyed by buckets, not exact counts)."""
    pts = rng.random((2000, 3)).astype(np.float32)
    qs = rng.random((384, 3)).astype(np.float32)
    ns = NeighborSearch(pts, SearchParams(radius=0.1, k=8), SearchOpts())
    ns.executor.warmup(qs)
    for _ in range(3):
        qs = np.clip(qs + rng.normal(0, 0.002, qs.shape).astype(np.float32),
                     0, 1)
        ns.query(qs)
        st = ns.executor.stats()
        assert st["last"]["compilations"] == 0
        assert st["launcher_cache_entries"] == 1


def test_warmup_stats_surface(rng):
    pts = rng.random((1000, 3)).astype(np.float32)
    qs = rng.random((200, 3)).astype(np.float32)
    ns = NeighborSearch(pts, SearchParams(radius=0.1, k=4), SearchOpts())
    st = ns.executor.warmup(qs)
    assert st["queries"] == 1
    assert st["launches"] >= 1
    assert st["signatures"] >= 1
    assert "jit_cache_sizes" in st
    assert ns.report.t_search > 0


def test_capture_plan_replay_matches_direct_query(rng):
    """capture_plan/execute(reuse=...) is public eager surface (the session
    now replays plans on device, core/api.py, but eager steppers can still
    capture once and replay): a replayed margin-inflated plan must match a
    direct query exactly in knn mode, with zero host planning on replay."""
    pts = rng.random((1500, 3)).astype(np.float32)
    qs = rng.random((384, 3)).astype(np.float32)
    params = SearchParams(radius=0.1, k=8, knn_window="exact")
    ns = NeighborSearch(pts, params, SearchOpts())
    handle = ns.executor.capture_plan(qs, margin=1)
    res_r = ns.executor.execute(qs, reuse=handle)
    res_d = NeighborSearch(pts, params, SearchOpts()).query(qs)
    for a, b in zip(_result_tuple(res_r), _result_tuple(res_d)):
        d2a, d2b = np.asarray(a), np.asarray(b)
        if d2a.dtype == np.float32 or d2a.dtype == np.float64:
            np.testing.assert_array_equal(d2a, d2b)
    np.testing.assert_array_equal(np.asarray(res_r.counts),
                                  np.asarray(res_d.counts))
    last = ns.executor.stats()["last"]
    assert last["plan_reused"] and last["plan_fetches"] == 0


def test_cache_hit_miss_accounting(rng):
    """The unified-registry counters tell the full plan/compile cache
    story: misses on first sight, hits on repeats, a fresh shape is a new
    miss, and invalidate() starts the count again from cold."""
    pts = rng.random((1500, 3)).astype(np.float32)
    qs = rng.random((384, 3)).astype(np.float32)
    ns = NeighborSearch(pts, SearchParams(radius=0.1, k=8), SearchOpts())
    ex = ns.executor

    ns.query(qs)                       # cold: both caches miss
    st = ex.stats()
    assert st["plan_cache_misses"] == 1 and st["plan_cache_hits"] == 0
    assert st["launcher_cache_misses"] == 1
    assert st["launcher_cache_hits"] == 0

    ns.query(qs)                       # repeat: both caches hit
    st = ex.stats()
    assert st["plan_cache_hits"] == 1 and st["plan_cache_misses"] == 1
    assert st["launcher_cache_hits"] == 1
    assert st["launcher_cache_misses"] == 1
    assert st["last"]["plan_cache_hit"]
    assert st["last"]["launcher_cache_hit"]

    qs2 = rng.random((512, 3)).astype(np.float32)
    ns.query(qs2)                      # new shape: new plan, new launcher
    st = ex.stats()
    assert st["plan_cache_misses"] == 2
    assert st["launcher_cache_misses"] == 2
    assert not st["last"]["plan_cache_hit"]

    ex.invalidate()                    # respec analogue: cold again
    st = ex.stats()
    assert st["invalidations"] == 1
    assert st["plan_cache_entries"] == 0
    assert st["launcher_cache_entries"] == 0
    ns.query(qs)
    st = ex.stats()
    assert st["plan_cache_misses"] == 3
    assert not st["last"]["plan_cache_hit"]


def test_warmup_yields_zero_compile_misses(rng):
    """warmup() populates both caches: the next same-shape query must see
    zero compile (launcher) misses and a plan-cache hit."""
    pts = rng.random((1200, 3)).astype(np.float32)
    qs = rng.random((256, 3)).astype(np.float32)
    ns = NeighborSearch(pts, SearchParams(radius=0.1, k=8), SearchOpts())
    ns.executor.warmup(qs)
    before = ns.executor.stats()["launcher_cache_misses"]
    ns.query(qs)
    st = ns.executor.stats()
    assert st["launcher_cache_misses"] == before
    assert st["last"]["compilations"] == 0
    assert st["last"]["plan_cache_hit"]
