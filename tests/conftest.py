# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only dryrun.py forces 512 placeholder devices.
import functools
import sys
import types

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# hypothesis fallback shim: this container cannot pip-install hypothesis, and
# without it 5 of 10 test modules die at import. When the real package is
# absent we register a minimal stand-in that degrades @given property tests
# to a fixed-seed multi-example run, so the real assertions still execute.
# Only the strategy surface these tests use is implemented (integers, floats,
# lists, tuples, sampled_from, booleans).
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    _N_EXAMPLES = 5          # fixed-seed examples per property test

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    def _lists(elem, min_size=0, max_size=10, **_kw):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elem.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def _tuples(*elems):
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    def _settings(**kw):
        def deco(fn):
            fn._stub_settings = kw
            return fn
        return deco

    def _given(*strategies):
        def deco(fn):
            max_examples = getattr(fn, "_stub_settings",
                                   {}).get("max_examples", _N_EXAMPLES)
            n_examples = min(int(max_examples), _N_EXAMPLES)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0)
                for _ in range(n_examples):
                    drawn = [s.draw(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)

            # pytest follows __wrapped__ to introspect fixture params; the
            # drawn params must not look like fixtures
            del wrapper.__wrapped__
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _st.tuples = _tuples

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def rng():
    return np.random.default_rng(0)
