# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only dryrun.py forces 512 placeholder devices.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
