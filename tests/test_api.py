"""Functional pytree-first API contract tests (DESIGN.md section 8):
parity with the eager host-planned path, composition under jit and vmap
(bitwise vs per-scene), zero mid-trace host syncs, the traced margin/
staleness contract, grad-safety, the one-shot index cache, and the
public-API snapshot."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
from repro.core import (NeighborSearch, SearchOpts, SearchParams,
                        neighbor_search)
from repro.kernels.ref import brute_force_search


def _d2(res):
    d = np.asarray(res.distances2)
    return np.where(np.isinf(d), -1.0, d)


def _assert_indices_valid(res, pts, qs, radius):
    ri = np.asarray(res.indices)
    valid = ri >= 0
    rd = np.asarray(res.distances2)
    assert (rd[valid] <= radius * radius + 1e-6).all()
    recompute = np.sum(
        (np.asarray(qs)[:, None] - np.asarray(pts)[np.clip(ri, 0, None)])
        ** 2, -1)
    np.testing.assert_allclose(recompute[valid], rd[valid], atol=1e-5)


def _scene(rng, n=1500, nq=397):
    return (rng.random((n, 3)).astype(np.float32),
            rng.random((nq, 3)).astype(np.float32))


PARAMS = SearchParams(radius=0.11, k=8, knn_window="exact")


def test_query_matches_eager_neighborsearch(rng):
    """Acceptance: the traced path must match the eager host-planned
    executor exactly — knn distances bitwise (both paths run the identical
    per-tile ops; bundling may widen eager windows but the exact-window
    guarantee makes the k-nearest set identical) and counts bitwise."""
    pts, qs = _scene(rng)
    res_e = NeighborSearch(pts, PARAMS, SearchOpts()).query(qs)
    res_f = api.query(api.build_index(pts, PARAMS, SearchOpts()), qs)
    np.testing.assert_array_equal(_d2(res_e), _d2(res_f))
    np.testing.assert_array_equal(np.asarray(res_e.counts),
                                  np.asarray(res_f.counts))
    _assert_indices_valid(res_f, pts, qs, PARAMS.radius)
    # and against the brute-force oracle
    _oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(qs),
                                     PARAMS.radius, PARAMS.k)
    np.testing.assert_allclose(
        _d2(res_f), np.where(np.isinf(np.asarray(od)), -1.0,
                             np.asarray(od)), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(oc), np.asarray(res_f.counts))


def test_query_under_jit_zero_host_syncs(rng):
    """jax.jit(api.query) must trace end-to-end — any mid-trace host sync
    (np.asarray / device_get on a tracer) raises TracerArrayConversionError
    — produce bitwise-identical results, and compile exactly once."""
    pts, qs = _scene(rng)
    index = api.build_index(pts, PARAMS, SearchOpts())
    jitted = jax.jit(api.query)
    res_j = jitted(index, qs)
    res_f = api.query(index, qs)
    np.testing.assert_array_equal(np.asarray(res_j.indices),
                                  np.asarray(res_f.indices))
    np.testing.assert_array_equal(_d2(res_j), _d2(res_f))
    np.testing.assert_array_equal(np.asarray(res_j.counts),
                                  np.asarray(res_f.counts))
    jitted(index, qs)
    assert jitted._cache_size() == 1


def test_vmap_over_stacked_scenes_bitwise(rng):
    """Acceptance: vmap over 4 stacked independent same-spec scenes matches
    the per-scene results bitwise — multi-scene batching is just vmap."""
    params = SearchParams(radius=0.1, k=8, knn_window="exact")
    scenes = [rng.random((1200, 3)).astype(np.float32) for _ in range(4)]
    qss = [rng.random((256, 3)).astype(np.float32) for _ in range(4)]
    index0 = api.build_index(scenes[0], params, SearchOpts())
    spec = index0.spec
    idxs = [index0] + [api.build_index(s, params, SearchOpts(), spec=spec)
                       for s in scenes[1:]]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *idxs)
    qstack = jnp.stack([jnp.asarray(q) for q in qss])
    bat = jax.jit(jax.vmap(api.query))(stacked, qstack)
    for b in range(4):
        one = api.query(idxs[b], qss[b])
        np.testing.assert_array_equal(np.asarray(bat.indices[b]),
                                      np.asarray(one.indices))
        np.testing.assert_array_equal(np.asarray(bat.distances2[b]),
                                      np.asarray(one.distances2))
        np.testing.assert_array_equal(np.asarray(bat.counts[b]),
                                      np.asarray(one.counts))


def test_build_index_traceable_with_explicit_spec(rng):
    """build_index is pure given a spec (composes under jit); without one
    it needs concrete points and must say so under a trace."""
    pts, qs = _scene(rng, n=800, nq=128)
    spec = api.build_index(pts, PARAMS).spec
    res = jax.jit(
        lambda p, q: api.query(api.build_index(p, PARAMS, spec=spec), q)
    )(pts, qs)
    ref = api.query(api.build_index(pts, PARAMS, spec=spec), qs)
    np.testing.assert_array_equal(np.asarray(res.indices),
                                  np.asarray(ref.indices))
    with pytest.raises(TypeError, match="choose_grid_spec"):
        jax.jit(lambda p: api.build_index(p, PARAMS))(pts)


def test_update_index_matches_fresh_build(rng):
    pts, qs = _scene(rng)
    index = api.build_index(pts, PARAMS, SearchOpts())
    moved = np.clip(pts + rng.normal(0, 0.004, pts.shape), 0.0,
                    1.0).astype(np.float32)
    index2, stats = api.update_index(index, moved)
    assert int(stats.overflow) >= 0 and int(stats.oob) == 0
    np.testing.assert_allclose(
        float(stats.max_disp2),
        np.max(np.sum((moved - pts) ** 2, axis=-1)), rtol=1e-6)
    fresh = api.build_index(moved, PARAMS, SearchOpts(), spec=index.spec)
    res_u = api.query(index2, qs)
    res_f = api.query(fresh, qs)
    np.testing.assert_array_equal(np.asarray(res_u.indices),
                                  np.asarray(res_f.indices))
    np.testing.assert_array_equal(_d2(res_u), _d2(res_f))


def test_margin_plan_stays_exact_under_drift(rng):
    """The traced staleness contract: a plan captured with margin=2 stays
    exact (knn distances/counts vs a fresh plan at the NEW positions) while
    every point drifts less than half a cell — the session's lax.cond
    replay branch is sound."""
    pts, _ = _scene(rng, n=1200)
    index = api.build_index(pts, PARAMS, SearchOpts())
    plan = api.plan_query(index, pts, margin=2)
    cell = index.spec.cell_size
    # bounded drift: per-axis uniform keeps every |delta| < 0.4 * cell
    delta = rng.uniform(-0.4 * cell / np.sqrt(3), 0.4 * cell / np.sqrt(3),
                        pts.shape).astype(np.float32)
    moved = np.clip(pts + delta, 0.0, 1.0).astype(np.float32)
    index2, _stats = api.update_index(index, moved)
    replayed = api.execute_plan(index2, moved, plan)
    fresh = api.query(
        api.build_index(moved, PARAMS, SearchOpts(), spec=index.spec), moved)
    np.testing.assert_array_equal(_d2(replayed), _d2(fresh))
    np.testing.assert_array_equal(np.asarray(replayed.counts),
                                  np.asarray(fresh.counts))


def test_explicit_w_ladder_stays_exact(rng):
    """SearchOpts.w_ladder coarsens the traced switch ladder; queries round
    up to the nearest ladder window, so results stay exact."""
    pts, qs = _scene(rng)
    res_ref = api.query(api.build_index(pts, PARAMS, SearchOpts()), qs)
    res_lad = api.query(
        api.build_index(pts, PARAMS, SearchOpts(w_ladder=(2,))), qs)
    np.testing.assert_array_equal(_d2(res_ref), _d2(res_lad))
    np.testing.assert_array_equal(np.asarray(res_ref.counts),
                                  np.asarray(res_lad.counts))


def test_w_ladder_with_partitioning_disabled_stays_exact(rng):
    """Regression: with partitioning inactive there are no per-query
    levels, so an explicit (smaller-than-full) ladder must not shadow the
    full-radius window — every query still searches w_full."""
    pts, qs = _scene(rng)
    res_ref = api.query(
        api.build_index(pts, PARAMS, SearchOpts(partition=False)), qs)
    res_lad = api.query(
        api.build_index(pts, PARAMS,
                        SearchOpts(partition=False, w_ladder=(1,))), qs)
    np.testing.assert_array_equal(_d2(res_ref), _d2(res_lad))
    np.testing.assert_array_equal(np.asarray(res_ref.indices),
                                  np.asarray(res_lad.indices))
    np.testing.assert_array_equal(np.asarray(res_ref.counts),
                                  np.asarray(res_lad.counts))


# ---------------------------------------------------------------------------
# single-program Pallas pipeline (level-segmented launches, DESIGN.md s3)
# ---------------------------------------------------------------------------

PALLAS_OPTS = SearchOpts(use_pallas=True, query_tile=128)


def test_pallas_traced_bitwise_parity_under_jit(rng):
    """Acceptance: jax.jit(api.query) with SearchOpts(use_pallas=True)
    compiles the level-segmented fused path end-to-end and produces
    distances/counts bitwise-equal to the jnp traced path."""
    pts, qs = _scene(rng)
    res_j = api.query(api.build_index(pts, PARAMS, SearchOpts()), qs)
    index_p = api.build_index(pts, PARAMS, PALLAS_OPTS)
    jitted = jax.jit(api.query)
    res_p = jitted(index_p, qs)
    np.testing.assert_array_equal(_d2(res_j), _d2(res_p))
    np.testing.assert_array_equal(np.asarray(res_j.counts),
                                  np.asarray(res_p.counts))
    _assert_indices_valid(res_p, pts, qs, PARAMS.radius)
    # one compiled program, reused on the second call (the jit cache is
    # shared across jax.jit(api.query) wrappers, so assert no growth)
    cache = jitted._cache_size()
    jitted(index_p, qs)
    assert jitted._cache_size() == cache


def test_pallas_traced_vmap_bitwise(rng):
    """Acceptance: vmap over stacked same-spec scenes through the fused
    path matches the per-scene results bitwise — the level-segmented
    launches (one masked kernel launch per ladder level) batch where the
    per-tile lax.switch would have executed every branch."""
    params = SearchParams(radius=0.1, k=8, knn_window="exact")
    scenes = [rng.random((1200, 3)).astype(np.float32) for _ in range(3)]
    qss = [rng.random((256, 3)).astype(np.float32) for _ in range(3)]
    index0 = api.build_index(scenes[0], params, PALLAS_OPTS)
    idxs = [index0] + [api.build_index(s, params, PALLAS_OPTS,
                                       spec=index0.spec)
                       for s in scenes[1:]]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *idxs)
    qstack = jnp.stack([jnp.asarray(q) for q in qss])
    bat = jax.jit(jax.vmap(api.query))(stacked, qstack)
    for b in range(3):
        one = api.query(idxs[b], qss[b])
        np.testing.assert_array_equal(np.asarray(bat.distances2[b]),
                                      np.asarray(one.distances2))
        np.testing.assert_array_equal(np.asarray(bat.counts[b]),
                                      np.asarray(one.counts))


def test_pallas_traced_range_mode_counts(rng):
    """The skip-sphere-test entries of the segmented ladder are exact:
    range-mode counts match the oracle and every returned index is within
    the radius (the megacell that held >= K in-sphere points stays inside
    the escalated shared window, bounding the streamed top-K)."""
    pts, qs = _scene(rng)
    params = SearchParams(radius=0.1, k=8, mode="range")
    res = jax.jit(api.query)(api.build_index(pts, params, PALLAS_OPTS), qs)
    _oi, _od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(qs),
                                      params.radius, params.k)
    np.testing.assert_array_equal(np.asarray(oc), np.asarray(res.counts))
    _assert_indices_valid(res, pts, qs, params.radius)


def test_segment_launches_safety_valve(rng, monkeypatch):
    """REPRO_SEGMENT_LAUNCHES=0 (read per call, not at import) falls the
    fused traced path back to the jnp lax.switch dispatch — results stay
    bitwise identical either way."""
    pts, qs = _scene(rng, n=900, nq=200)
    index = api.build_index(pts, PARAMS, PALLAS_OPTS)
    res_seg = api.query(index, qs)
    monkeypatch.setenv("REPRO_SEGMENT_LAUNCHES", "0")
    res_jnp = api.query(index, qs)
    jaxpr = str(jax.make_jaxpr(api.query)(index, qs))
    assert "pallas_call" not in jaxpr          # valve really took the exit
    np.testing.assert_array_equal(_d2(res_seg), _d2(res_jnp))
    np.testing.assert_array_equal(np.asarray(res_seg.counts),
                                  np.asarray(res_jnp.counts))


def test_pallas_anchors_on_device_zero_host_syncs(rng):
    """Anchors-on-device: a jitted execute_plan over the fused path must
    trace end-to-end (any mid-trace host sync — np.asarray / device_get on
    a tracer, as the old host-metadata anchor computation did — raises
    TracerArrayConversionError) and compile exactly once (trace-counting
    pattern from tests/test_executor.py)."""
    pts, qs = _scene(rng, n=900, nq=200)
    index = api.build_index(pts, PARAMS, PALLAS_OPTS)

    jitted = jax.jit(api.execute_plan)
    plan = api.plan_query(index, qs)
    res = jitted(index, qs, plan)
    ref = api.execute_plan(index, qs, plan)
    np.testing.assert_array_equal(_d2(res), _d2(ref))
    cache = jitted._cache_size()
    jitted(index, qs, plan)
    assert jitted._cache_size() == cache
    # and the fused kernel really is on the traced path: the jaxpr contains
    # one pallas launch per segment-ladder level (the masked launches),
    # not a lax.switch over window branches
    from repro.kernels.ops import segment_levels
    jaxpr = str(jax.make_jaxpr(api.execute_plan)(index, qs, plan))
    n_levels = len(segment_levels(plan.ladder, index.spec.dims))
    assert jaxpr.count("pallas_call") == n_levels


def test_grad_safety(rng):
    """Distances are differentiable w.r.t. the query positions through the
    whole traced pipeline (schedule sort, switch dispatch, top-k, scatter)."""
    pts, qs = _scene(rng, n=600, nq=128)
    index = api.build_index(pts, PARAMS, SearchOpts())

    def loss(q):
        res = api.query(index, q)
        return jnp.sum(jnp.where(jnp.isinf(res.distances2), 0.0,
                                 res.distances2))

    g = jax.grad(loss)(jnp.asarray(qs))
    assert g.shape == qs.shape
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.abs(g).sum()) > 0.0


def test_one_shot_cache_reuses_searcher(rng):
    """Satellite contract: repeated one-shot neighbor_search over the same
    point set must reuse ONE cached searcher (plan/compile caches warm)
    instead of rebuilding per call."""
    api.searcher_cache_clear()
    pts, qs = _scene(rng, n=900, nq=200)
    ns1 = api.cached_searcher(pts, PARAMS)
    ns2 = api.cached_searcher(pts, PARAMS)
    assert ns1 is ns2
    assert api.searcher_cache_stats()["entries"] == 1
    res1 = neighbor_search(pts, qs, PARAMS.radius, PARAMS.k)
    res2 = neighbor_search(pts, qs, PARAMS.radius, PARAMS.k)
    # one-shot calls with the same (points, params, opts) hit the same entry
    assert api.searcher_cache_stats()["entries"] == 1
    np.testing.assert_array_equal(np.asarray(res1.indices),
                                  np.asarray(res2.indices))
    other = rng.random((900, 3)).astype(np.float32)
    assert api.cached_searcher(other, PARAMS) is not ns1
    assert api.searcher_cache_stats()["entries"] == 2
    api.searcher_cache_clear()


# ---------------------------------------------------------------------------
# public-API snapshot
# ---------------------------------------------------------------------------

# Frozen export list of repro.api. If this assertion fails you changed the
# public surface: update the snapshot AND add a CHANGES.md note in the same
# commit.
API_SNAPSHOT = (
    "GridSpec",
    "NeighborIndex",
    "QueryError",
    "QueryPlan",
    "SearchOpts",
    "SearchParams",
    "SearchResult",
    "UpdateStats",
    "build_index",
    "cached_searcher",
    "execute_plan",
    "launch_signatures",
    "plan_query",
    "query",
    "query_concat",
    "searcher_cache_clear",
    "searcher_cache_stats",
    "update_index",
    "validate_queries",
)


def test_public_api_snapshot():
    assert tuple(sorted(api.__all__)) == API_SNAPSHOT, (
        "repro.api exports changed — update API_SNAPSHOT in tests/test_api.py"
        " and record the change in CHANGES.md")
    for name in API_SNAPSHOT:
        assert callable(getattr(api, name)) or hasattr(api, name)
