"""Multi-device tests (subprocess: jax device count is locked at first
init, so each test spawns a fresh interpreter with 8 host devices)."""
import os
import subprocess
import sys

import pytest

ENV = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_distributed_search_exact():
    out = _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import distributed_neighbor_search
from repro.core.types import SearchParams
from repro.kernels.ref import brute_force_search
from repro.launch.mesh import make_mesh_compat
rng = np.random.default_rng(3)
pts = rng.random((4000, 3)).astype(np.float32)
qs = rng.random((900, 3)).astype(np.float32)
r, K = 0.07, 8
mesh = make_mesh_compat((4, 2), ("data", "model"))
res = distributed_neighbor_search(mesh, pts, qs, SearchParams(radius=r, k=K))
oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(qs), r, K)
assert np.array_equal(np.asarray(oi), np.asarray(res.indices))
assert np.array_equal(np.asarray(oc), np.asarray(res.counts))
print("EXACT-MATCH")
""")
    assert "EXACT-MATCH" in out


def test_sharded_train_step_matches_single_device():
    """The pjit'd train step on a 4x2 mesh must produce the same loss as the
    unsharded step (same math, different partitioning)."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import smoke_config
from repro.data.pipeline import make_batch
from repro.models.config import get_config
from repro.models.model import init_params
from repro.sharding.rules import (param_pspecs, opt_pspecs, make_shard_fn,
                                  named_sharding_tree)
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step
from repro.launch.mesh import make_test_mesh

cfg = smoke_config(get_config("grok-1-314b"))   # MoE path under sharding
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
opt_cfg = OptConfig(lr=1e-3, warmup_steps=1)
opt = init_opt_state(params, opt_cfg)
batch = make_batch(cfg, 8, 16, key)
batch = jax.tree.map(lambda a: a[None], batch)

ref_step = jax.jit(make_train_step(cfg, opt_cfg))
_, _, m_ref = ref_step(params, opt, batch)

mesh = make_test_mesh((4, 2), ("data", "model"))
shard = make_shard_fn(mesh)
p_sh = named_sharding_tree(param_pspecs(params, mesh), mesh)
o_sh = named_sharding_tree(opt_pspecs(jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params), mesh), mesh)
with mesh:
    sh_step = jax.jit(make_train_step(cfg, opt_cfg, shard=shard),
                      in_shardings=(p_sh, o_sh, None),
                      out_shardings=(p_sh, o_sh, None))
    p2, o2, m_sh = sh_step(params, opt, batch)
a, b = float(m_ref["loss"]), float(m_sh["loss"])
assert abs(a - b) < 1e-3, (a, b)
print("LOSS-MATCH", a, b)
""")
    assert "LOSS-MATCH" in out


def test_production_mesh_shapes():
    out = _run("""
import numpy as np
import jax
from repro.launch.mesh import make_production_mesh
# only 8 devices here: expect the helpful error for the 256-chip mesh
try:
    make_production_mesh()
    print("UNEXPECTED-OK")
except RuntimeError as e:
    assert "xla_force_host_platform_device_count" in str(e)
    print("GUARDED")
""")
    assert "GUARDED" in out


def test_remesh_elastic():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_test_mesh
from repro.train.fault_tolerance import remesh
x = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
mesh_a = make_test_mesh((8,), ("data",))
mesh_b = make_test_mesh((4,), ("data",))
specs = {"w": P("data", None)}
xa = remesh(x, mesh_a, specs)
xb = remesh(xa, mesh_b, specs)
assert np.array_equal(np.asarray(xb["w"]), np.arange(32).reshape(8, 4))
print("REMESH-OK")
""")
    assert "REMESH-OK" in out


def test_sharded_session_oracle_and_zero_host_routing():
    """THE sharded acceptance property (DESIGN.md section 6): a 4-slab
    ShardedSession stepping a drifting trajectory is oracle-equal to the
    single-device search on every frame — including frames where particles
    migrate across slab faces — and performs ZERO host-side routing after
    construction (the host_routings counter stays at 1)."""
    out = _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import SearchParams, ShardedSession
from repro.kernels.ref import brute_force_search
rng = np.random.default_rng(2)
n = 1200
pts = rng.random((n, 3)).astype(np.float32)
params = SearchParams(radius=0.1, k=8, knn_window="exact")
sess = ShardedSession(pts, params, n_slabs=4)
vel = rng.normal(0, 0.004, (n, 3)).astype(np.float32)
for f in range(6):
    rs = sess.step(pts)
    oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(pts),
                                    0.1, 8)
    np.testing.assert_array_equal(np.asarray(oc), np.asarray(rs.counts))
    ds = np.where(np.isinf(np.asarray(rs.distances2)), -1,
                  np.asarray(rs.distances2))
    dr = np.where(np.isinf(np.asarray(od)), -1, np.asarray(od))
    np.testing.assert_allclose(ds, dr, atol=1e-5)
    np.testing.assert_array_equal(np.sort(np.asarray(rs.indices), 1),
                                  np.sort(np.asarray(oi), 1))
    pts = np.clip(pts + vel, 0.0, 1.0).astype(np.float32)  # coherent drift
st = sess.stats()
assert st["migrated"] > 0, st          # faces were actually crossed
assert st["host_routings"] == 1, st    # construction only — zero per-step
assert st["steps"] == 6 and st["reroutes"] == 0, st
print("SHARDED-ORACLE-OK", st["migrated"])
""")
    assert "SHARDED-ORACLE-OK" in out


def test_sharded_session_steady_state_replays():
    """Below-threshold drift on a multi-slab mesh replays every slab's
    captured plan on device: fast steps with zero host routing. Drift is
    y/z-only so slab/halo membership is frame-stable — any x-crossing
    (migration, halo entry/exit) changes a row's occupant and correctly
    forces that slab to replan."""
    out = _run("""
import numpy as np
from repro.core import SearchParams, ShardedSession
rng = np.random.default_rng(5)
pts = rng.random((900, 3)).astype(np.float32)
sess = ShardedSession(pts, SearchParams(radius=0.1, k=8,
                                        knn_window="exact"), n_slabs=4)
sess.step(pts)
drift = np.zeros_like(pts)
for _ in range(4):
    drift[:, 1:] = rng.normal(0, 0.0002, (900, 2))
    pts = np.clip(pts + drift, 0.0, 1.0).astype(np.float32)
    sess.step(pts)
st = sess.stats()
assert st["fast_steps"] >= 3, st
assert st["host_routings"] == 1, st
assert st["migrated"] == 0, st
print("SHARDED-STEADY-OK")
""")
    assert "SHARDED-STEADY-OK" in out


def test_sharded_migration_into_nearly_full_slab():
    """Regression: an arrival from the RIGHT neighbor sits in the second
    half of the migration buffer; the free-row merge must rank ARRIVALS
    against the free-row count, not buffer positions — otherwise a slab
    with fewer free rows than migrate_cap spuriously flags exhaustion and
    forces a host re-route although rows are free."""
    out = _run("""
import numpy as np
from repro.core import SearchParams, ShardedSession
from repro.core.shards import ShardOpts
from repro.kernels.ref import brute_force_search
import jax.numpy as jnp
rng = np.random.default_rng(11)
# slab 1 fuller than slab 0 so point_cap (slack 1.0) leaves slab 0 only
# a few free rows — fewer than migrate_cap
pts = rng.random((200, 3)).astype(np.float32)
pts[:96, 0] = pts[:96, 0] * 0.5          # slab 0: 96 rows
pts[96:, 0] = 0.5 + pts[96:, 0] * 0.5    # slab 1: 104 rows
shopts = ShardOpts(point_slack=1.0, domain_margin_radii=2.0)
params = SearchParams(radius=0.05, k=4, knn_window="exact")
sess = ShardedSession(pts, params, n_slabs=2, shopts=shopts)
assert sess.layout.point_cap == 104
sess.step(pts)
# walk one slab-1 point leftwards across the face: it must merge into
# one of slab 0's free rows without tripping the exhausted fallback
pts2 = pts.copy()
pts2[100, 0] = 0.49
res = sess.step(pts2)
st = sess.stats()
assert st["migrated"] >= 1, st
assert st["reroutes"] == 0 and st["host_routings"] == 1, st
oi, od, oc = brute_force_search(jnp.asarray(pts2), jnp.asarray(pts2),
                                0.05, 4)
np.testing.assert_array_equal(np.asarray(oc), np.asarray(res.counts))
np.testing.assert_array_equal(np.sort(np.asarray(res.indices), 1),
                              np.sort(np.asarray(oi), 1))
print("MIGRATE-MERGE-OK")
""")
    assert "MIGRATE-MERGE-OK" in out


def test_distributed_routing_edge_cases():
    """Satellite: empty slabs, all-points-in-one-slab skew, and queries
    landing exactly on slab faces must all round-trip in original query
    order with correct global ids."""
    out = _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import distributed_neighbor_search
from repro.core.types import SearchParams
from repro.kernels.ref import brute_force_search
from repro.launch.mesh import make_mesh_compat

def check(pts, qs, r=0.08, K=8):
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    res = distributed_neighbor_search(mesh, pts, qs,
                                      SearchParams(radius=r, k=K))
    oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(qs),
                                    r, K)
    np.testing.assert_array_equal(np.asarray(oc), np.asarray(res.counts))
    np.testing.assert_array_equal(np.sort(np.asarray(res.indices), 1),
                                  np.sort(np.asarray(oi), 1))
    dg = np.where(np.isinf(np.asarray(res.distances2)), -1,
                  np.asarray(res.distances2))
    dr = np.where(np.isinf(np.asarray(od)), -1, np.asarray(od))
    np.testing.assert_allclose(dg, dr, atol=1e-5)

rng = np.random.default_rng(7)

# 1. empty middle slabs: bimodal x — slabs 1..2 own (almost) nothing
pts = rng.random((1500, 3)).astype(np.float32)
pts[:, 0] = np.where(rng.random(1500) < 0.5, pts[:, 0] * 0.1,
                     0.9 + pts[:, 0] * 0.1)
qs = rng.random((300, 3)).astype(np.float32)   # queries everywhere,
check(pts, qs)                                  # incl. the empty slabs
print("EDGE-EMPTY-OK")

# 2. all-points-in-one-slab skew: one outlier stretches the domain so
# ~all points land in slab 0
pts = rng.random((1000, 3)).astype(np.float32)
pts[:, 0] *= 0.05
pts[0, 0] = 1.0
qs = rng.random((200, 3)).astype(np.float32)
check(pts, qs)
print("EDGE-SKEW-OK")

# 3. queries exactly on slab faces (and points near them): the face
# position must route to exactly one slab and find cross-face neighbors
# through the halo
pts = rng.random((2000, 3)).astype(np.float32)
qs = rng.random((256, 3)).astype(np.float32)
lo = pts[:, 0].min()
width = (pts[:, 0].max() - lo) / 4.0
for i, s in enumerate([1, 2, 3] * 40):          # exact face x-coords
    qs[i, 0] = np.float32(lo + s * width)
check(pts, qs)
print("EDGE-FACE-OK")
""")
    assert "EDGE-EMPTY-OK" in out
    assert "EDGE-SKEW-OK" in out
    assert "EDGE-FACE-OK" in out


def test_api_query_composes_with_shard_map():
    """The functional core's acceptance composition: stacked same-spec
    scenes sharded over a device mesh axis, a vmapped api.query per shard —
    per-scene results must match the single-device call bitwise."""
    out = _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro.api as api
from repro.core import SearchOpts, SearchParams, choose_grid_spec
from repro.core.distributed import _shard_map, _SHARD_MAP_KW
from repro.launch.mesh import make_mesh_compat
rng = np.random.default_rng(5)
B = 8
params = SearchParams(radius=0.1, k=8, knn_window="exact")
scenes = [rng.random((900, 3)).astype(np.float32) for _ in range(B)]
qss = [rng.random((128, 3)).astype(np.float32) for _ in range(B)]
spec = choose_grid_spec(np.concatenate(scenes), params.radius)
idxs = [api.build_index(s, params, SearchOpts(), spec=spec) for s in scenes]
stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *idxs)
qstack = jnp.stack([jnp.asarray(q) for q in qss])
mesh = make_mesh_compat((B,), ("pod",))
fn = _shard_map(lambda idx, qs: jax.vmap(api.query)(idx, qs),
                mesh=mesh, in_specs=(P("pod"), P("pod")),
                out_specs=P("pod"), **_SHARD_MAP_KW)
res = jax.jit(fn)(stacked, qstack)
for b in range(B):
    one = api.query(idxs[b], qss[b])
    assert np.array_equal(np.asarray(res.indices[b]), np.asarray(one.indices))
    assert np.array_equal(np.asarray(res.distances2[b]),
                          np.asarray(one.distances2))
    assert np.array_equal(np.asarray(res.counts[b]), np.asarray(one.counts))
print("SHARD-MATCH")
""")
    assert "SHARD-MATCH" in out
