"""Sharded-scene subsystem unit tests that need NO device mesh (the traced
routing/unrouting scatters, layout planning, and the degenerate 1-slab
mesh, which runs on the single CPU device). The multi-slab paths — halo
exchange, migration, query split — run under 8 forced host devices in
tests/test_multidevice.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SearchOpts, SearchParams, ShardedSession,
                        SimulationSession, shard_scene)
from repro.core.shards import (STATIC_SCENE_OPTS, ShardOpts, plan_layout,
                               route_points, route_queries,
                               unroute_results)
from repro.kernels.ref import brute_force_search

PARAMS = SearchParams(radius=0.12, k=8, knn_window="exact")


def test_route_points_roundtrip(rng):
    """Every point lands in exactly one slab slot, with its global id, in
    the slab its x-coordinate selects; zero overflow when the layout was
    planned over the same points."""
    pts = rng.random((700, 3)).astype(np.float32)
    layout = plan_layout(pts, PARAMS, 4)
    spts, sids, ovf = route_points(layout, jnp.asarray(pts))
    assert int(ovf) == 0
    sids_np = np.asarray(sids)
    spts_np = np.asarray(spts)
    seen = sids_np[sids_np >= 0]
    assert sorted(seen.tolist()) == list(range(700))    # each id once
    for s in range(4):
        row = sids_np[s]
        sel = row[row >= 0]
        np.testing.assert_array_equal(spts_np[s][row >= 0], pts[sel])
        # routed rows belong to this slab
        slab = np.clip(((pts[sel, 0] - layout.lo_x)
                        / np.float32(layout.slab_width)).astype(int),
                       0, 3)
        assert (slab == s).all()


def test_route_points_overflow_detected(rng):
    """A slab fuller than point_cap reports the dropped count instead of
    silently truncating (the session's re-route trigger)."""
    pts = rng.random((300, 3)).astype(np.float32)
    layout = plan_layout(pts, PARAMS, 2)
    tight = dataclasses.replace(layout, point_cap=100)
    _p, _i, ovf = route_points(tight, jnp.asarray(pts))
    slab = np.clip(((pts[:, 0] - layout.lo_x) / layout.slab_width)
                   .astype(int), 0, 1)
    expected = int(np.maximum(np.bincount(slab, minlength=2) - 100,
                              0).sum())
    assert int(ovf) == expected and expected > 0


def test_route_queries_roundtrip_and_unroute(rng):
    """Queries split round-robin over the qsplit columns and scatter back
    to the original order through unroute_results."""
    pts = rng.random((500, 3)).astype(np.float32)
    qs = rng.random((123, 3)).astype(np.float32)
    layout = plan_layout(pts, PARAMS, 3, n_qsplit=2, queries=qs)
    rq, qid, ovf = route_queries(layout, jnp.asarray(qs))
    assert int(ovf) == 0
    qid_np = np.asarray(qid)
    seen = qid_np[qid_np >= 0]
    assert sorted(seen.tolist()) == list(range(123))
    # fabricate per-slot results = the query id itself; unroute must give
    # back identity in original order
    k = 4
    gidx = jnp.broadcast_to(qid[..., None], qid.shape + (k,))
    d2 = jnp.where(gidx >= 0, 0.5, jnp.inf).astype(jnp.float32)
    cnt = jnp.where(qid >= 0, 7, 0).astype(jnp.int32)
    oi, od, oc = unroute_results(qid, gidx, d2, cnt, 123)
    np.testing.assert_array_equal(np.asarray(oi)[:, 0], np.arange(123))
    assert (np.asarray(oc) == 7).all()


def test_plan_layout_caps_cover_data(rng):
    pts = rng.random((900, 3)).astype(np.float32)
    layout = plan_layout(pts, PARAMS, 4, shopts=STATIC_SCENE_OPTS)
    slab = np.clip(((pts[:, 0] - layout.lo_x) / layout.slab_width)
                   .astype(int), 0, 3)
    assert np.bincount(slab, minlength=4).max() <= layout.point_cap
    assert layout.halo_cap >= 1 and layout.migrate_cap >= 1
    # boost inflates every headroom knob
    boosted = plan_layout(pts, PARAMS, 4, boost=2.0)
    assert boosted.point_cap >= layout.point_cap
    assert boosted.spec.capacity >= layout.spec.capacity


def test_shard_scene_one_slab_matches_single_device(rng):
    """S=1 degenerates to the functional core (no halo, no neighbors):
    the full sharded program — traced route, shard_map(api.query),
    unroute — must match brute force exactly on the single CPU device."""
    pts = rng.random((600, 3)).astype(np.float32)
    qs = rng.random((150, 3)).astype(np.float32)
    index = shard_scene(pts, PARAMS, n_slabs=1, queries=qs)
    res = index.query(qs)
    oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(qs),
                                    PARAMS.radius, PARAMS.k)
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(res.indices))
    np.testing.assert_array_equal(np.asarray(oc), np.asarray(res.counts))
    dr = np.where(np.isinf(np.asarray(od)), -1, np.asarray(od))
    dg = np.where(np.isinf(np.asarray(res.distances2)), -1,
                  np.asarray(res.distances2))
    np.testing.assert_allclose(dg, dr, atol=1e-6)


def test_shard_scene_composes_with_pallas(rng):
    """use_pallas routes the per-slab search through the level-segmented
    fused schedule with the slab's dynamic origin feeding the anchor
    computation — results stay oracle-exact."""
    pts = rng.random((400, 3)).astype(np.float32)
    qs = rng.random((100, 3)).astype(np.float32)
    params = SearchParams(radius=0.15, k=8, knn_window="exact")
    index = shard_scene(pts, params, n_slabs=1,
                        opts=SearchOpts(use_pallas=True, query_tile=128),
                        queries=qs)
    res = index.query(qs)
    oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(qs),
                                    0.15, 8)
    np.testing.assert_array_equal(np.asarray(oc), np.asarray(res.counts))
    np.testing.assert_array_equal(
        np.sort(np.asarray(res.indices), axis=1),
        np.sort(np.asarray(oi), axis=1))


def test_sharded_session_one_slab_matches_simulation_session(rng):
    """The slab-resident session on a 1-slab mesh steps the identical
    trajectory as a single-device SimulationSession: same counts, same
    distance multisets, same neighbor id sets, zero host routing after
    construction."""
    pts = rng.random((500, 3)).astype(np.float32)
    sh = ShardedSession(pts, PARAMS, n_slabs=1)
    ref = SimulationSession(pts, PARAMS)
    for _ in range(5):
        rs = sh.step(pts)
        rr = ref.step(pts)
        np.testing.assert_array_equal(np.asarray(rs.counts),
                                      np.asarray(rr.counts))
        ds = np.where(np.isinf(np.asarray(rs.distances2)), -1,
                      np.asarray(rs.distances2))
        dr = np.where(np.isinf(np.asarray(rr.distances2)), -1,
                      np.asarray(rr.distances2))
        np.testing.assert_allclose(ds, dr, atol=1e-6)
        np.testing.assert_array_equal(
            np.sort(np.asarray(rs.indices), axis=1),
            np.sort(np.asarray(rr.indices), axis=1))
        pts = np.clip(pts + rng.normal(0, 0.0006, pts.shape),
                      0.0, 1.0).astype(np.float32)
    st = sh.stats()
    assert st["host_routings"] == 1          # construction only
    assert st["steps"] == 5 and st["fast_steps"] >= 1
    assert st["reroutes"] == 0


def test_sharded_session_reroute_fallback(rng):
    """A scene the frozen layout cannot hold (mass escape past the domain
    margin) trips the exhausted flag and falls back to ONE host re-route,
    after which results are exact again."""
    pts = rng.random((300, 3)).astype(np.float32)
    sess = ShardedSession(pts, PARAMS, n_slabs=1)
    sess.step(pts)
    far = (pts + np.float32([3.0, 0.0, 0.0])).astype(np.float32)
    res = sess.step(far)
    st = sess.stats()
    assert st["reroutes"] == 1 and st["host_routings"] == 2
    oi, od, oc = brute_force_search(jnp.asarray(far), jnp.asarray(far),
                                    PARAMS.radius, PARAMS.k)
    np.testing.assert_array_equal(np.asarray(oc), np.asarray(res.counts))
    np.testing.assert_array_equal(
        np.sort(np.asarray(res.indices), axis=1),
        np.sort(np.asarray(oi), axis=1))
    # and the session keeps stepping normally afterwards
    sess.step(far)
    assert sess.stats()["reroutes"] == 1


def test_sharded_session_reroute_disabled_raises(rng):
    pts = rng.random((200, 3)).astype(np.float32)
    sess = ShardedSession(pts, PARAMS, n_slabs=1,
                          shopts=ShardOpts(auto_reroute=False))
    sess.step(pts)
    with pytest.raises(RuntimeError, match="exhausted"):
        sess.step(pts + np.float32([5.0, 0, 0]))


def test_query_cap_overflow_raises(rng):
    """A query batch denser than the planned cap fails loudly with the
    re-plan hint instead of silently dropping queries."""
    pts = rng.random((400, 3)).astype(np.float32)
    few = rng.random((10, 3)).astype(np.float32)
    index = shard_scene(pts, PARAMS, n_slabs=1, queries=few,
                        shopts=STATIC_SCENE_OPTS)
    many = rng.random((200, 3)).astype(np.float32)
    with pytest.raises(RuntimeError, match="query_cap"):
        index.query(many)
