"""Training infrastructure: optimizer, checkpoint/restart determinism,
fault injection, straggler monitor, data-stream resumability."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data.pipeline import make_batch, synthetic_stream
from repro.models.config import get_config
from repro.models.model import init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import ResilientLoop, StragglerMonitor
from repro.train.optimizer import (OptConfig, _qdecode, _qencode,
                                   apply_updates, init_opt_state)
from repro.train.train_step import make_train_step

KEY = jax.random.PRNGKey(0)
CFG = smoke_config(get_config("qwen1.5-110b"))


def _setup(opt_cfg=None):
    opt_cfg = opt_cfg or OptConfig(lr=1e-2, warmup_steps=1)
    params = init_params(CFG, KEY)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(CFG, opt_cfg))
    return params, opt, step, opt_cfg


def _batch(step=0, n_micro=1, b=4, s=16):
    key = jax.random.fold_in(KEY, step)
    batch = make_batch(CFG, b, s, key)
    return jax.tree.map(
        lambda a: a.reshape((n_micro, b // n_micro) + a.shape[1:]), batch)


def test_loss_decreases():
    params, opt, step, _ = _setup()
    losses = []
    batch = _batch()
    for i in range(15):
        params, opt, m = step(params, opt, batch)  # overfit one batch
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_microbatch_equivalence():
    """grad accumulation over 4 microbatches == single big batch step."""
    params, opt, step, opt_cfg = _setup()
    p1, o1, m1 = step(params, opt, _batch(n_micro=1))
    p4, o4, m4 = step(params, opt, _batch(n_micro=4))
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    # fp reassociation of accumulated grads is amplified by Adam's
    # 1/sqrt(v) where v is tiny -> loose elementwise tolerance
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_quantized_moments_roundtrip():
    x = jax.random.normal(KEY, (1000,)) * 0.03
    q = _qencode(x)
    y = _qdecode(q, x.shape)
    # absmax int8: error bounded by half a quantization step per block
    step = float(np.max(np.asarray(q["scale"])))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                               atol=0.51 * step + 1e-7)
    assert q["code"].dtype == jnp.int8


def test_quantized_sqrt_moments_bounded():
    from repro.train.optimizer import _qdecode_sqrt, _qencode_sqrt
    v = jnp.abs(jax.random.normal(KEY, (1000,))) * 1e-4
    v = v.at[::7].set(1e-12)      # tiny second moments inside the block
    q = _qencode_sqrt(v)
    y = _qdecode_sqrt(q, v.shape)
    # decode floor: no zero-collapse (the update-explosion guard)
    assert float(jnp.min(y)) > 0
    big = np.asarray(v) > 1e-6
    np.testing.assert_allclose(np.asarray(y)[big], np.asarray(v)[big],
                               rtol=0.2)


def test_quantized_adam_tracks_fp32():
    cfg_q = OptConfig(lr=1e-2, warmup_steps=1, quantize_moments=True)
    params, opt_f, step_f, _ = _setup()
    opt_q = init_opt_state(params, cfg_q)
    step_q = jax.jit(make_train_step(CFG, cfg_q))
    b = _batch()
    pf, qf = params, params
    of, oq = opt_f, opt_q
    for i in range(5):
        pf, of, mf = step_f(pf, of, b)
        qf, oq, mq = step_q(qf, oq, b)
    assert abs(float(mf["loss"]) - float(mq["loss"])) < 0.15


def test_grad_clip_engages():
    params, opt, _, _ = _setup()
    big = jax.tree.map(lambda p: jnp.ones_like(p) * 1e3, params)
    cfg = OptConfig(lr=1e-2, grad_clip=1.0, warmup_steps=1)
    p2, o2, m = apply_updates(params, big, opt, cfg)
    assert float(m["grad_norm"]) > 1.0
    # update magnitude bounded by lr * (1 + wd-ish): clip engaged
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p2),
                                jax.tree.leaves(params)))
    assert delta < 0.2


def test_checkpoint_roundtrip_and_retention(tmp_path):
    params, opt, step, _ = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save(s, params, opt, extra={"cursor": s})
    assert mgr.all_steps() == [20, 30]   # retention pruned step 10
    p2, o2, man = mgr.restore(params, opt)
    assert man["step"] == 30
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_determinism(tmp_path):
    """train 6 straight == train 3, checkpoint, restore, train 3."""
    params, opt, step, _ = _setup()

    pa, oa = params, opt
    for s in range(6):
        pa, oa, ma = step(pa, oa, _batch(step=s))

    pb, ob = params, opt
    for s in range(3):
        pb, ob, mb = step(pb, ob, _batch(step=s))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, pb, ob)
    pc, oc, _ = mgr.restore(pb, ob)
    for s in range(3, 6):
        pc, oc, mc = step(pc, oc, _batch(step=s))
    np.testing.assert_allclose(float(ma["loss"]), float(mc["loss"]),
                               rtol=1e-6)


def test_resilient_loop_recovers_from_failure(tmp_path):
    params, opt, step, _ = _setup()
    mgr = CheckpointManager(str(tmp_path))
    fail_at = {"n": 7}
    calls = {"n": 0}

    def flaky_step(p, o, b):
        calls["n"] += 1
        if calls["n"] == fail_at["n"]:
            raise RuntimeError("injected node failure")
        return step(p, o, b)

    def stream_fn(start):
        return (_batch(step=s) for s in range(start, 10_000))

    loop = ResilientLoop(mgr, save_every=2, max_restarts=2)
    p, o, log = loop.run(flaky_step, params, opt, stream_fn, n_steps=10)
    assert loop.restarts == 1
    assert len(log) == 10          # all 10 steps eventually completed


def test_straggler_monitor():
    mon = StragglerMonitor(factor=3.0)
    for _ in range(10):
        assert not mon.observe(0.1)
    assert mon.observe(1.0)        # 10x the EMA -> flagged
    assert mon.flagged == 1
    assert not mon.observe(0.1)    # EMA not polluted by the straggler


def test_stream_resumable():
    a = list(zip(range(3), synthetic_stream(CFG, 2, 8, start_step=2)))
    b = list(zip(range(3), synthetic_stream(CFG, 2, 8, start_step=2)))
    for (_, x), (_, y) in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x["tokens"]),
                                      np.asarray(y["tokens"]))
