"""Multi-tenant serving layer tests (repro.serve, DESIGN.md section 10).

The contracts:

1. **bitwise parity + one sync per drained batch** — a mixed multi-scene,
   mixed-signature trace served through the micro-batcher returns results
   bitwise-identical to per-request ``api.query``, while the obs sync
   counter shows exactly one host sync per drained batch (and far fewer
   batches than requests);
2. **registry residency** — LRU eviction releases compiled state and fires
   callbacks, readmission re-warms the caches and keeps correctness, and a
   scene evicted between admission and drain fails its futures instead of
   wedging the service;
3. **backpressure** — past the high-water mark ``submit`` rejects with a
   retry-after estimate, and the queue drains back to empty and accepts
   again;
4. **scheduling** — drain order is deterministic under a seeded trace
   (pipelining depth included), buckets honor the max-wait deadline and
   max-batch size, and per-scene round-robin keeps a cold tenant from
   starving behind a hot one.
"""
import numpy as np
import pytest

import repro.api as api
from repro import obs
from repro.core import (SearchOpts, SearchParams, SimulationSession)
from repro.serve import (NeighborService, Rejected, SceneRegistry,
                         ServeOpts)

P_A = SearchParams(radius=0.11, k=8, knn_window="exact")
P_B = SearchParams(radius=0.15, k=4, knn_window="exact")


@pytest.fixture(autouse=True)
def clean_obs():
    obs.reset()
    yield
    obs.configure()
    obs.reset()


def _scenes(rng, sizes=(1100, 800)):
    return {f"s{i}": rng.random((n, 3)).astype(np.float32)
            for i, n in enumerate(sizes)}


def _trace(rng, scene_ids, n_requests, params=(P_A, P_B),
           qmin=5, qmax=60):
    out = []
    for i in range(n_requests):
        sid = scene_ids[int(rng.integers(len(scene_ids)))]
        p = params[int(rng.integers(len(params)))]
        q = rng.random((int(rng.integers(qmin, qmax + 1)), 3)) \
            .astype(np.float32)
        out.append((sid, p, q))
    return out


def _assert_bitwise(got, ref):
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_array_equal(np.asarray(got.counts),
                                  np.asarray(ref.counts))
    da = np.where(np.isinf(np.asarray(got.distances2)), -1.0,
                  np.asarray(got.distances2))
    db = np.where(np.isinf(np.asarray(ref.distances2)), -1.0,
                  np.asarray(ref.distances2))
    np.testing.assert_array_equal(da, db)


# ------------------------------------------------ parity + one-sync contract


def test_serve_bitwise_parity_and_one_sync_per_batch(rng):
    """Acceptance: every request in a mixed multi-scene trace comes back
    bitwise-identical to per-request ``api.query``, with exactly one host
    sync per drained batch (obs counter) and real micro-batching (batches
    << requests)."""
    scenes = _scenes(rng)
    svc = NeighborService(ServeOpts(max_batch=512, max_pending=100_000))
    for sid, pts in scenes.items():
        svc.register_scene(sid, pts)

    trace = _trace(rng, list(scenes), 28)
    futures = [(sid, p, q, svc.submit(sid, q, p)) for sid, p, q in trace]
    reports = svc.drain()

    st = svc.stats()
    assert st["host_syncs"] == st["batches"] == len(reports)
    assert len(reports) < len(futures)           # coalescing happened
    assert st["resolved"] == len(futures)
    assert st["queue_depth"] == 0

    refs = {}
    for sid, p, q, fut in futures:
        key = (sid, p)
        if key not in refs:
            refs[key] = api.build_index(scenes[sid], p)
        _assert_bitwise(fut.result(timeout=30), api.query(refs[key], q))


def test_query_concat_entry_point_matches_per_request(rng):
    """The core batch-concat entry (``api.query_concat``) is the drain
    contract in miniature: one launch, per-request bitwise results."""
    pts = rng.random((900, 3)).astype(np.float32)
    index = api.build_index(pts, P_A)
    qs = [rng.random((n, 3)).astype(np.float32) for n in (7, 33, 128, 1)]
    outs = api.query_concat(index, qs)
    assert len(outs) == len(qs)
    for q, got in zip(qs, outs):
        _assert_bitwise(got, api.query(index, q))
    assert api.query_concat(index, []) == []


def test_session_backed_scene_serves_current_frame(rng):
    """A live SimulationSession registers as a dynamic scene: drained
    queries hit the session's current index leaves."""
    pts = rng.random((600, 3)).astype(np.float32)
    sess = SimulationSession(pts, P_A)
    sess.step(pts)
    pts2 = np.clip(pts + rng.normal(0, 0.004, pts.shape),
                   0, 1).astype(np.float32)
    sess.step(pts2)

    svc = NeighborService()
    svc.register_session("sim", sess)
    q = rng.random((40, 3)).astype(np.float32)
    fut = svc.submit("sim", q, P_A)
    svc.drain()
    _assert_bitwise(fut.result(timeout=30), api.query(sess.index, q))
    # a mismatched signature against a session-backed scene fails loudly
    with pytest.raises(ValueError):
        svc.registry.resolve("sim", P_B)


# ------------------------------------------------------- registry residency


def test_registry_lru_eviction_and_readmission_rewarm(rng):
    scenes = _scenes(rng, sizes=(700, 500))
    evicted = []
    svc = NeighborService(ServeOpts(scenes=1))
    svc.registry.on_evict(lambda sid, rec: evicted.append(sid))

    svc.register_scene("s0", scenes["s0"])
    q = rng.random((24, 3)).astype(np.float32)
    fut = svc.submit("s0", q, P_A)
    svc.drain()
    v0 = svc.registry.get("s0").variant(P_A)
    assert v0.compiled_programs() >= 1           # serve program compiled
    ref = api.query(api.build_index(scenes["s0"], P_A), q)
    _assert_bitwise(fut.result(), ref)

    svc.register_scene("s1", scenes["s1"])       # capacity 1 -> evicts s0
    assert evicted == ["s0"]
    assert "s0" not in svc.registry and "s1" in svc.registry
    assert v0.fn is None                         # compiled state released
    assert v0.searcher.executor.stats()["plan_cache_entries"] == 0
    with pytest.raises(KeyError):
        svc.submit("s0", q, P_A)

    # readmission: fresh variant, re-warms, same bitwise results
    svc.register_scene("s0", scenes["s0"])
    v1 = svc.registry.get("s0").variant(P_A)
    assert v1 is not v0 and v1.compiled_programs() == 0
    fut2 = svc.submit("s0", q, P_A)
    svc.drain()
    assert v1.compiled_programs() >= 1
    _assert_bitwise(fut2.result(), ref)


def test_scene_evicted_between_admission_and_drain_fails_futures(rng):
    scenes = _scenes(rng, sizes=(600, 500, 400))
    svc = NeighborService(ServeOpts(scenes=2))
    svc.register_scene("s0", scenes["s0"])
    svc.register_scene("s1", scenes["s1"])
    q = rng.random((16, 3)).astype(np.float32)
    fut_dead = svc.submit("s0", q, P_A)
    fut_live = svc.submit("s1", q, P_A)
    svc.register_scene("s2", scenes["s2"])       # evicts LRU = s0
    reports = svc.drain()
    assert isinstance(fut_dead.exception(), KeyError)
    assert fut_live.exception() is None
    _assert_bitwise(fut_live.result(),
                    api.query(api.build_index(scenes["s1"], P_A), q))
    assert {r.scene_id for r in reports} == {"s1"}
    assert svc.stats()["failed_batches"] == 1
    assert svc.queue_depth() == 0


def test_registry_warm_on_register(rng):
    pts = rng.random((500, 3)).astype(np.float32)
    svc = NeighborService()
    svc.register_scene("s", pts, warm=(P_A, 64))
    v = svc.registry.get("s").variant(P_A)
    assert v.compiled_programs() == 1
    # the warmed bucket serves without further compiles
    fut = svc.submit("s", rng.random((20, 3)).astype(np.float32), P_A)
    svc.drain()
    assert fut.done() and v.compiled_programs() == 1


# ------------------------------------------------------------- backpressure


def test_backpressure_rejects_past_high_water_then_drains(rng):
    pts = rng.random((600, 3)).astype(np.float32)
    svc = NeighborService(ServeOpts(max_pending=100, max_batch=256))
    svc.register_scene("s", pts)
    q = rng.random((40, 3)).astype(np.float32)
    accepted = [svc.submit("s", q, P_A), svc.submit("s", q, P_A)]
    with pytest.raises(Rejected) as exc_info:
        svc.submit("s", q, P_A)                  # 120 pending > 100
    assert exc_info.value.retry_after_s > 0
    assert svc.stats()["rejected"] == 1

    svc.drain()                                  # drains to empty...
    assert svc.queue_depth() == 0
    fut = svc.submit("s", q, P_A)                # ...and admits again
    svc.drain()
    assert fut.done()
    for f in accepted:
        assert f.done()


# --------------------------------------------------------------- scheduling


def test_deterministic_drain_order_under_seeded_trace():
    """Same seeded trace, fresh services (different pipeline depths
    included) -> identical batch sequence (scene, signature, request seqs,
    padded size)."""

    def run(pipeline):
        rng = np.random.default_rng(7)
        scenes = _scenes(rng)
        svc = NeighborService(ServeOpts(max_batch=256, pipeline=pipeline,
                                        max_pending=100_000))
        for sid, pts in scenes.items():
            svc.register_scene(sid, pts)
        for sid, p, q in _trace(rng, list(scenes), 30):
            svc.submit(sid, q, p)
        return [(r.scene_id, r.params, r.seqs, r.nq, r.pad_n)
                for r in svc.drain()]

    first = run(pipeline=1)
    assert first == run(pipeline=1) == run(pipeline=0) == run(pipeline=3)
    assert len(first) > 1


def test_bucket_deadline_and_max_batch(rng):
    pts = rng.random((500, 3)).astype(np.float32)
    svc = NeighborService(ServeOpts(max_batch=64, max_wait_s=10.0))
    svc.register_scene("s", pts)
    q = rng.random((8, 3)).astype(np.float32)

    svc.submit("s", q, P_A, now=0.0)
    assert svc.pump(now=0.5) == []               # not full, not due
    assert svc.queue_depth() == 1
    reports = svc.pump(now=10.5)                 # past the deadline
    assert len(reports) == 1 and svc.queue_depth() == 0

    # a full bucket drains immediately, capped at max_batch rows
    for i in range(10):
        svc.submit("s", q, P_A, now=20.0)
    reports = svc.pump(now=20.0)
    assert len(reports) >= 1
    assert all(r.nq <= 64 for r in reports)
    assert sum(len(r.seqs) for r in reports) == 8    # 2 of 10 not yet due
    assert svc.queue_depth() == 2
    svc.drain()


def test_per_scene_fairness_no_starvation(rng):
    """A hot tenant needing several drains cannot starve a cold one: the
    round-robin interleaves scenes, so the cold scene's single request
    drains within the first two batches."""
    scenes = _scenes(rng, sizes=(700, 500))
    svc = NeighborService(ServeOpts(max_batch=128, max_pending=100_000))
    for sid, pts in scenes.items():
        svc.register_scene(sid, pts)
    hot = rng.random((64, 3)).astype(np.float32)
    for _ in range(6):
        svc.submit("s0", hot, P_A)               # 6 batches' worth? 3 of 2
    cold_fut = svc.submit("s1", rng.random((16, 3)).astype(np.float32),
                          P_A)
    reports = svc.drain()
    cold_pos = next(i for i, r in enumerate(reports)
                    if r.scene_id == "s1")
    assert cold_pos <= 1
    assert cold_fut.done()
    assert sum(r.scene_id == "s0" for r in reports) >= 3


def test_standalone_registry_capacity_validation():
    with pytest.raises(ValueError):
        SceneRegistry(capacity=0)
    with pytest.raises(ValueError):
        ServeOpts(max_batch=0)
    with pytest.raises(ValueError):
        ServeOpts(pipeline=-1)


def test_background_pump_resolves_futures(rng):
    """The daemon pump drains due buckets without explicit pump calls
    (real streaming callers)."""
    pts = rng.random((500, 3)).astype(np.float32)
    svc = NeighborService(ServeOpts(max_wait_s=0.01))
    svc.register_scene("s", pts, warm=(P_A, 256))
    svc.start(poll_s=0.005)
    try:
        fut = svc.submit("s", rng.random((12, 3)).astype(np.float32), P_A)
        res = fut.result(timeout=30.0)
        assert np.asarray(res.indices).shape == (12, P_A.k)
    finally:
        svc.stop()
    assert svc.queue_depth() == 0
