"""End-to-end search correctness vs the brute-force oracle, across the
paper's optimization ablation matrix (Fig. 13) and point distributions."""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (NeighborSearch, SearchOpts, SearchParams,
                        neighbor_search)
from repro.data.pointclouds import clustered_cloud, kitti_like_cloud, \
    uniform_cloud
from repro.kernels.ref import brute_force_search


def _check_knn_exact(pts, qs, r, k, opts):
    oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(qs), r, k)
    res = neighbor_search(pts, qs, r, k, mode="knn", opts=opts,
                          knn_window="exact")
    d_ref = np.where(np.isinf(np.asarray(od)), -1.0, np.asarray(od))
    d_got = np.where(np.isinf(np.asarray(res.distances2)), -1.0,
                     np.asarray(res.distances2))
    np.testing.assert_allclose(d_got, d_ref, atol=1e-5)
    assert np.array_equal(np.asarray(oc), np.asarray(res.counts))


@pytest.mark.parametrize("schedule,partition,bundle", list(
    itertools.product([False, True], repeat=3)))
def test_knn_ablation_matrix(rng, schedule, partition, bundle):
    pts = rng.random((1500, 3)).astype(np.float32)
    qs = rng.random((400, 3)).astype(np.float32)
    opts = SearchOpts(schedule=schedule, partition=partition, bundle=bundle)
    _check_knn_exact(pts, qs, 0.12, 8, opts)


@pytest.mark.parametrize("maker", [uniform_cloud, kitti_like_cloud,
                                   clustered_cloud])
def test_knn_distributions(maker):
    pts = maker(3000, seed=1)
    qs = maker(500, seed=2)
    _check_knn_exact(pts, qs, 0.1, 8, SearchOpts())


def test_range_counts_and_radius(rng):
    pts = rng.random((2500, 3)).astype(np.float32)
    qs = rng.random((600, 3)).astype(np.float32)
    r, k = 0.09, 16
    oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(qs), r, k)
    res = neighbor_search(pts, qs, r, k, mode="range")
    ri = np.asarray(res.indices)
    rd = np.asarray(res.distances2)
    assert np.array_equal(np.asarray(oc), np.asarray(res.counts))
    valid = ri >= 0
    assert (rd[valid] <= r * r + 1e-6).all()
    # returned indices are actual points at the reported distances
    d_check = np.sum((qs[:, None, :] - pts[np.clip(ri, 0, None)]) ** 2, -1)
    np.testing.assert_allclose(np.where(valid, d_check, 0),
                               np.where(valid, rd, 0), atol=1e-5)


def test_knn_heuristic_recall_uniform(rng):
    """Paper's heuristic window (section 5.1) is approximate by design;
    on locally-uniform data it should be near-exact."""
    pts = rng.random((4000, 3)).astype(np.float32)
    qs = rng.random((500, 3)).astype(np.float32)
    r, k = 0.1, 8
    oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(qs), r, k)
    res = neighbor_search(pts, qs, r, k, mode="knn", knn_window="heuristic")
    ref_sets = [set(row[row >= 0].tolist()) for row in np.asarray(oi)]
    got_sets = [set(row[row >= 0].tolist()) for row in
                np.asarray(res.indices)]
    hits = sum(len(a & b) for a, b in zip(ref_sets, got_sets))
    total = max(sum(len(a) for a in ref_sets), 1)
    assert hits / total > 0.95, hits / total


@given(st.integers(20, 300), st.integers(1, 16),
       st.floats(0.03, 0.4), st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=20)
def test_knn_exact_property(n, k, r, seed):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 3)).astype(np.float32)
    qs = rng.random((max(n // 3, 5), 3)).astype(np.float32)
    _check_knn_exact(pts, qs, r, k, SearchOpts())


def test_pallas_path_matches_jnp_path(rng):
    pts = rng.random((2000, 3)).astype(np.float32)
    qs = rng.random((500, 3)).astype(np.float32)
    params = SearchParams(radius=0.1, k=8, mode="knn", knn_window="exact")
    res_j = NeighborSearch(pts, params, SearchOpts()).query(qs)
    res_p = NeighborSearch(pts, params,
                           SearchOpts(use_pallas=True,
                                      query_tile=128)).query(qs)
    np.testing.assert_allclose(
        np.where(np.isinf(np.asarray(res_j.distances2)), -1,
                 np.asarray(res_j.distances2)),
        np.where(np.isinf(np.asarray(res_p.distances2)), -1,
                 np.asarray(res_p.distances2)), atol=1e-5)
    assert np.array_equal(np.asarray(res_j.counts), np.asarray(res_p.counts))


def test_query_equals_point_is_own_neighbor(rng):
    pts = rng.random((500, 3)).astype(np.float32)
    res = neighbor_search(pts, pts[:50], 0.1, 1, mode="knn")
    np.testing.assert_array_equal(np.asarray(res.indices)[:, 0],
                                  np.arange(50))
    # expanded-form distance: |q|^2+|p|^2-2qp is ~eps, not exactly 0
    np.testing.assert_allclose(np.asarray(res.distances2)[:, 0], 0.0,
                               atol=1e-6)


def test_report_breakdown_populated(rng):
    pts = rng.random((1000, 3)).astype(np.float32)
    qs = rng.random((200, 3)).astype(np.float32)
    ns = NeighborSearch(pts, SearchParams(radius=0.1, k=4))
    ns.query(qs)
    assert ns.report.num_partitions >= 1
    assert len(ns.report.bundles) >= 1
    assert ns.report.t_search > 0
