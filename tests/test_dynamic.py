"""SimulationSession contract tests (DESIGN.md sections 7-8): per-step
exactness against a fresh-search oracle on moving points (including across
respecs), the device-resident staleness steady state (zero host
replanning, zero per-step stats fetches, zero retraces), and the update
kernel itself."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SearchOpts, SearchParams, SessionOpts,
                        SimulationSession, update_cell_grid)
from repro.kernels.ref import brute_force_search


def _assert_oracle_exact(res, pts, qs, radius, k, mode="knn"):
    """Counts exact and every returned index verified by distance
    recomputation; in knn mode the distance multiset is exact too (range
    mode returns *any* bounded-K in-radius subset per the paper's
    interface, so only counts/validity are contractual — mirroring
    test_search.test_range_counts_and_radius)."""
    _oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(qs),
                                     radius, k)
    np.testing.assert_array_equal(np.asarray(oc), np.asarray(res.counts))
    if mode == "knn":
        d_ref = np.where(np.isinf(np.asarray(od)), -1.0, np.asarray(od))
        d_got = np.where(np.isinf(np.asarray(res.distances2)), -1.0,
                         np.asarray(res.distances2))
        np.testing.assert_allclose(d_got, d_ref, atol=1e-5)
    ri = np.asarray(res.indices)
    valid = ri >= 0
    rd = np.asarray(res.distances2)
    assert (rd[valid] <= radius * radius + 1e-6).all()
    recompute = np.sum(
        (np.asarray(qs)[:, None] - np.asarray(pts)[np.clip(ri, 0, None)])
        ** 2, -1)
    np.testing.assert_allclose(recompute[valid], rd[valid], atol=1e-5)


def _drift(rng, pts, sigma):
    return np.clip(pts + rng.normal(0, sigma, pts.shape), 0.0,
                   1.0).astype(np.float32)


@pytest.mark.parametrize("mode", ["knn", "range"])
def test_session_exact_on_moving_sequence(rng, mode):
    """Randomized moving-point sequence: every step — fast replays and
    replans alike — must match the brute-force oracle on the *current*
    positions."""
    pts = rng.random((1400, 3)).astype(np.float32)
    params = SearchParams(radius=0.1, k=8, mode=mode, knn_window="exact")
    sess = SimulationSession(pts, params)
    saw_fast = saw_replan = False
    for _ in range(7):
        res = sess.step(pts)
        _assert_oracle_exact(res, pts, pts, 0.1, 8, mode)
        saw_fast |= sess.report.fast
        saw_replan |= sess.report.replanned
        pts = _drift(rng, pts, 0.002)
    assert saw_fast and saw_replan      # both regimes actually exercised
    assert sess.stats()["respecs"] == 0


def test_session_external_queries_exact(rng):
    """Queries independent of the points, both moving."""
    pts = rng.random((1200, 3)).astype(np.float32)
    qs = rng.random((300, 3)).astype(np.float32)
    params = SearchParams(radius=0.12, k=8, knn_window="exact")
    sess = SimulationSession(pts, params)
    for _ in range(5):
        res = sess.step(pts, qs)
        _assert_oracle_exact(res, pts, qs, 0.12, 8)
        pts = _drift(rng, pts, 0.002)
        qs = _drift(rng, qs, 0.002)


def test_session_steady_state_zero_host_replanning(rng):
    """THE acceptance property: below-threshold steps perform no host-side
    work at all — the staleness decision is a device `lax.cond` (plan
    replayed on device), the per-step stats fetch is gone (stats_fetches
    stays 0), and the fused step program is not retraced."""
    pts = rng.random((1500, 3)).astype(np.float32)
    sess = SimulationSession(pts, SearchParams(radius=0.1, k=8))
    sess.step(pts)                              # capture + compile (force)
    pts = _drift(rng, pts, 0.0004)
    sess.step(pts)                              # compiles the replay variant
    cache = sess.stats()["step_cache_size"]
    for _ in range(4):
        pts = _drift(rng, pts, 0.0004)          # well below threshold
        sess.step(pts)
        assert sess.report.fast
        assert not sess.report.replanned and not sess.report.respecced
        # no retrace: the lax.cond replay re-enters the same compiled step
        assert sess.stats()["step_cache_size"] == cache
    st = sess.stats()
    assert st["fast_steps"] == 5 and st["replans"] == 1
    # the per-step stats fetch is gone from the fast path entirely
    assert st["stats_fetches"] == 0


def test_session_replans_when_displacement_exceeds_threshold(rng):
    pts = rng.random((1000, 3)).astype(np.float32)
    sess = SimulationSession(pts, SearchParams(radius=0.1, k=8))
    sess.step(pts)
    cell = sess.spec.cell_size
    # move one point a full cell: the max-displacement statistic must
    # trip the staleness threshold even though the mean drift is ~zero
    pts2 = pts.copy()
    pts2[17] += np.float32([cell, 0, 0])
    sess.step(pts2)
    assert sess.report.replanned and not sess.report.respecced
    assert sess.stats()["replans"] == 2


def test_session_respec_on_escape_and_overflow(rng):
    """Out-of-bounds and capacity-overflow both trigger the respec
    fallback, and results stay oracle-exact across it."""
    pts = rng.random((900, 3)).astype(np.float32) * 0.5
    params = SearchParams(radius=0.08, k=8, knn_window="exact")
    sess = SimulationSession(pts, params)
    sess.step(pts)
    old_spec = sess.spec

    far = (pts + np.float32([2.0, 0.0, 0.0])).astype(np.float32)
    res = sess.step(far)
    assert sess.report.respecced and sess.report.oob > 0
    assert sess.spec is not old_spec
    _assert_oracle_exact(res, far, far, 0.08, 8)

    # keep stepping after the respec: session still works and goes fast
    nxt = _drift(rng, far - np.float32([2.0, 0, 0]), 0.0) \
        + np.float32([2.0, 0, 0])
    res = sess.step((nxt + 0.0005).astype(np.float32))
    assert sess.report.fast

    # capacity overflow: pile a third of the cloud into one cell
    sess2 = SimulationSession(pts, params,
                              sopts=SessionOpts(capacity_slack=1.0))
    sess2.step(pts)
    squeezed = pts.copy()
    squeezed[:300] = pts[0]
    res = sess2.step(squeezed)
    assert sess2.report.respecced and sess2.report.overflow > 0
    _assert_oracle_exact(res, squeezed, squeezed, 0.08, 8)
    assert sess2.stats()["respecs"] == 1


def test_respec_hysteresis_logarithmic(rng):
    """Respec hysteresis (ROADMAP): each respec plans geometrically more
    headroom, so an adversarial workload that keeps outrunning the frozen
    spec — here a constant-velocity escape from the domain — triggers
    O(log frames) respecs, not one per frame, while every step stays
    oracle-exact."""
    pts = rng.random((400, 3)).astype(np.float32)
    params = SearchParams(radius=0.1, k=4, knn_window="exact")
    # max_dim bounds the dense grid as the escaping domain stretches (CPU
    # test budget); the hysteresis behavior under test is unaffected
    sess = SimulationSession(pts, params, sopts=SessionOpts(max_dim=48))
    steps = 24
    vel = np.float32([3.0 * 0.1, 0.0, 0.0])   # 3 radii per frame: the
    # initial 1-radius margin is outrun immediately and every frame after
    respec_frames = []
    for f in range(steps):
        cur = (pts + f * vel).astype(np.float32)
        res = sess.step(cur)
        if sess.report.respecced:
            respec_frames.append(f)
        # counts stay oracle-exact; the distance check needs a coordinate-
        # scaled tolerance because the expanded |q|^2+|p|^2-2qp form loses
        # f32 bits as the escaping cloud drifts far from the origin
        _oi, od, oc = brute_force_search(jnp.asarray(cur), jnp.asarray(cur),
                                         0.1, 4)
        np.testing.assert_array_equal(np.asarray(oc),
                                      np.asarray(res.counts))
        d_ref = np.where(np.isinf(np.asarray(od)), -1.0, np.asarray(od))
        d_got = np.where(np.isinf(np.asarray(res.distances2)), -1.0,
                         np.asarray(res.distances2))
        np.testing.assert_allclose(d_got, d_ref, atol=1e-5)
    respecs = sess.stats()["respecs"]
    # geometric margin growth: each respec buys ~2x more frames than the
    # last, so ceil(log2(total drift / initial margin)) + O(1) respecs
    assert respecs <= int(math.ceil(math.log2(steps * 3))) + 2, respecs
    assert respecs < steps / 2
    # and the bought headroom is real: the gaps between respecs grow
    gaps = np.diff([0] + respec_frames)
    assert respecs >= 2 and (gaps[-1] >= gaps[0])

    # growth disabled reverts to the old behavior: the same adversary
    # respecs on (nearly) every frame
    sess0 = SimulationSession(pts, params,
                              sopts=SessionOpts(respec_growth=1.0,
                                                max_dim=48))
    for f in range(10):
        sess0.step((pts + f * vel).astype(np.float32))
    assert sess0.stats()["respecs"] >= 8


def test_session_respec_disabled_raises(rng):
    pts = rng.random((400, 3)).astype(np.float32)
    sess = SimulationSession(pts, SearchParams(radius=0.1, k=4),
                             sopts=SessionOpts(auto_respec=False))
    sess.step(pts)
    with pytest.raises(RuntimeError, match="frozen grid"):
        sess.step(pts + np.float32([3.0, 0, 0]))


def test_session_retrace_contract_across_replans_and_respec(rng):
    """Replan and replay are the SAME compiled program (the two branches of
    the device `lax.cond`): an above-threshold step must not retrace, and
    only a respec — which changes the frozen spec the program specializes
    on — may compile new step variants. The session stays exact throughout."""
    pts = rng.random((1300, 3)).astype(np.float32)
    params = SearchParams(radius=0.1, k=8, knn_window="exact")
    sess = SimulationSession(pts, params)
    sess.step(pts)
    pts = _drift(rng, pts, 0.0003)
    sess.step(pts)                      # fast step (replay variant compiled)
    cache = sess.stats()["step_cache_size"]
    assert sess.report.fast
    # a replan with unchanged shapes re-enters the same compiled step: the
    # cond simply takes the other branch
    big = sess.spec.cell_size
    pts2 = pts.copy()
    pts2[3] += np.float32([big, 0, 0])
    res = sess.step(pts2)
    assert sess.report.replanned
    assert sess.stats()["step_cache_size"] == cache
    _assert_oracle_exact(res, pts2, pts2, 0.1, 8)
    # respec: new frozen spec -> the old spec's step variants are released
    # and replaced by the new specialization, exact results throughout
    pts3 = (pts2 + np.float32([4.0, 0, 0])).astype(np.float32)
    res = sess.step(pts3)
    assert sess.report.respecced
    assert sess.stats()["respecs"] == 1
    assert sess.stats()["step_cache_size"] == 1     # old variants dropped
    _assert_oracle_exact(res, pts3, pts3, 0.1, 8)
    # and the session re-enters the fast path on the new spec
    pts4 = _drift(rng, pts3 - np.float32([4.0, 0, 0]), 0.0002) \
        + np.float32([4.0, 0, 0])
    sess.step(pts4.astype(np.float32))
    assert sess.report.fast


def test_session_self_query_shares_device_buffer(rng):
    """step(points) and step(points, queries=points) are the same fast
    path, and results equal the explicit two-array call."""
    pts = rng.random((800, 3)).astype(np.float32)
    params = SearchParams(radius=0.1, k=8, knn_window="exact")
    s1 = SimulationSession(pts, params)
    s2 = SimulationSession(pts, params)
    r1 = s1.step(pts)
    r2 = s2.step(pts, qs_other := pts.copy())   # distinct array: full path
    np.testing.assert_array_equal(np.asarray(r1.counts),
                                  np.asarray(r2.counts))
    d1 = np.where(np.isinf(np.asarray(r1.distances2)), -1.0,
                  np.asarray(r1.distances2))
    d2 = np.where(np.isinf(np.asarray(r2.distances2)), -1.0,
                  np.asarray(r2.distances2))
    np.testing.assert_allclose(d1, d2, atol=1e-6)
    assert qs_other is not pts


def test_session_switching_query_sets_replans(rng):
    """Swapping between self-query and external queries must replan: the
    cached plan is anchored at the other set's positions (the displacement
    statistic does not track the swap), and results must stay exact."""
    pts = rng.random((700, 3)).astype(np.float32)
    qs = rng.random((700, 3)).astype(np.float32)   # same Nq as the points
    params = SearchParams(radius=0.11, k=8, knn_window="exact")
    sess = SimulationSession(pts, params)
    sess.step(pts)
    res = sess.step(pts, qs)
    assert sess.report.replanned
    _assert_oracle_exact(res, pts, qs, 0.11, 8)
    res = sess.step(pts)
    assert sess.report.replanned
    _assert_oracle_exact(res, pts, pts, 0.11, 8)


def test_session_pallas_path(rng):
    """The session composes with the fused-kernel search path (update
    kernel + knn tile kernel, both interpret-mode on CPU)."""
    pts = rng.random((600, 3)).astype(np.float32)
    params = SearchParams(radius=0.12, k=8, knn_window="exact")
    sess = SimulationSession(pts, params,
                             SearchOpts(use_pallas=True, query_tile=128))
    for _ in range(3):
        res = sess.step(pts)
        _assert_oracle_exact(res, pts, pts, 0.12, 8)
        pts = _drift(rng, pts, 0.0005)
    assert sess.stats()["fast_steps"] >= 1


def test_session_grid_donation_alias_safety(rng):
    """Grid-only donation (SessionOpts.donate_grid): the step donates the
    dense-grid leaves — always session-owned — while caller-aliased
    points/anchor buffers stay untouched. Forced ON here (the CPU backend
    ignores donation with a warning, but the donation *plumbing* — the
    grid split out as its own argument, no duplicate-donation, no donated
    caller buffer — is exercised identically), across replays, replans,
    and a respec."""
    import warnings
    pts = rng.random((800, 3)).astype(np.float32)
    params = SearchParams(radius=0.1, k=8, knn_window="exact")
    sess = SimulationSession(pts, params,
                             sopts=SessionOpts(donate_grid=True))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")          # CPU donation warning
        caller_buf = jnp.asarray(pts)
        res = sess.step(caller_buf)              # force/capture step
        _assert_oracle_exact(res, pts, pts, 0.1, 8)
        # the caller's device buffer must NOT have been donated away
        np.testing.assert_array_equal(np.asarray(caller_buf), pts)
        pts2 = _drift(rng, pts, 0.0003)
        res = sess.step(pts2)                    # replay step
        _assert_oracle_exact(res, pts2, pts2, 0.1, 8)
        assert sess.report.fast
        big = pts2.copy()
        big[5] += np.float32([sess.spec.cell_size, 0, 0])
        res = sess.step(big)                     # replan step
        _assert_oracle_exact(res, big, big, 0.1, 8)
        far = (big + np.float32([4.0, 0, 0])).astype(np.float32)
        res = sess.step(far)                     # respec step
        assert sess.report.respecced
        _assert_oracle_exact(res, far, far, 0.1, 8)

    # default (auto) on CPU disables donation: no warning path at all
    sess2 = SimulationSession(pts, params)
    res = sess2.step(pts)
    _assert_oracle_exact(res, pts, pts, 0.1, 8)


def test_update_cell_grid_matches_fresh_build(rng):
    """The incremental update must produce the bit-identical structure a
    fresh build over the moved points would."""
    from repro.core import build_cell_grid, choose_grid_spec
    pts = rng.random((1000, 3)).astype(np.float32)
    spec = choose_grid_spec(pts, 0.1, capacity_slack=2.0)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    moved = _drift(rng, pts, 0.01)
    g2, stats, ccoord = update_cell_grid(grid, jnp.asarray(moved),
                                         jnp.asarray(pts))
    fresh = build_cell_grid(jnp.asarray(moved), spec)
    np.testing.assert_array_equal(np.asarray(g2.dense),
                                  np.asarray(fresh.dense))
    np.testing.assert_array_equal(np.asarray(g2.sat),
                                  np.asarray(fresh.sat))
    np.testing.assert_array_equal(np.asarray(ccoord),
                                  np.asarray(spec.cell_of(
                                      jnp.asarray(moved))))
    assert int(stats.oob) == 0
    d2 = np.max(np.sum((moved - pts) ** 2, axis=-1))
    np.testing.assert_allclose(float(stats.max_disp2), d2, rtol=1e-6)


def test_update_kernel_matches_jnp_path(rng):
    """kernels/update_tile vs the jnp binning+stats: bit-identical cells,
    counters, and displacement statistic (incl. out-of-bounds points)."""
    from repro.core.grid import _bin_and_stats, choose_grid_spec
    from repro.kernels.update_tile import bin_disp_tile
    pts = rng.random((777, 3)).astype(np.float32)
    spec = choose_grid_spec(pts, 0.1)
    anchor = _drift(rng, pts, 0.01)
    moved = pts.copy()
    moved[7] = [9.0, 9.0, 9.0]
    moved[123] = [-4.0, 0.5, 0.5]
    cj, oj, dj = _bin_and_stats(spec, jnp.asarray(moved),
                                jnp.asarray(anchor))
    cp, op, dp = bin_disp_tile(jnp.asarray(moved), jnp.asarray(anchor),
                               spec, interpret=True)
    np.testing.assert_array_equal(np.asarray(cj), np.asarray(cp))
    assert int(oj) == int(op) == 2
    np.testing.assert_allclose(float(dj), float(dp), rtol=1e-6)
