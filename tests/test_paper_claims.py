"""Deterministic proxies for the paper's section 3.2/6.3 claims.

Wall-clock claims are hardware-specific; here we assert the *work-count*
mechanisms behind them, which are deterministic on any backend:
  Obs. 2 / Fig. 8: Step-2 candidate tests grow superlinearly with window
  width; partitioning shrinks them.  Scheduling claim (Obs. 1): Morton
  ordering raises the adjacent-query cell-sharing statistic (the coherence
  the paper measures via cache hit rates).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (NeighborSearch, SearchOpts, SearchParams,
                        build_cell_grid, choose_grid_spec,
                        coherence_statistic, schedule_queries)
from repro.core.search import window_search
from repro.data.pointclouds import kitti_like_cloud, uniform_cloud


def _candidate_count(pts, qs, w, cell=0.05):
    """Number of Step-2 (sphere-test) candidates a window search touches —
    the TPU analogue of the paper's IS-call count (Fig. 8)."""
    spec = choose_grid_spec(pts, radius=cell, cell_size=cell)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    ccoord = spec.cell_of(jnp.asarray(qs))
    from repro.core.grid import box_count, clamp_box
    lo, hi = clamp_box(spec, ccoord, w)
    return int(jnp.sum(box_count(grid.sat, lo, hi)))


def test_candidates_grow_superlinearly_with_window(rng):
    """Fig. 8: IS calls grow ~cubically with AABB width."""
    pts = rng.random((20000, 3)).astype(np.float32)
    qs = rng.random((500, 3)).astype(np.float32)
    counts = [_candidate_count(pts, qs, w) for w in (1, 2, 4)]
    assert counts[1] > counts[0] * 2           # superlinear
    assert counts[2] > counts[1] * 2
    # cubic-ish: doubling w (~doubling width) ~8x volume; allow slack for
    # boundary clamping
    assert counts[2] / counts[0] > 8


def test_partitioning_reduces_candidates(rng):
    """Section 5.1: per-partition windows do less Step-2 work than the
    monolithic full-radius window."""
    pts = rng.random((20000, 3)).astype(np.float32)
    qs = rng.random((1000, 3)).astype(np.float32)
    params = SearchParams(radius=0.3, k=8)
    ns = NeighborSearch(pts, params, SearchOpts(partition=True))
    ns.query(qs)
    w_full = ns.statics.w_full
    # work proxy: queries x candidate-window volume, partitioned vs
    # monolithic (the determinant of Step-2 work, Observation 2)
    vol_part = sum(b.count * (2 * b.w_search + 1) ** 3
                   for b in ns.report.bundles)
    vol_full = len(qs) * (2 * w_full + 1) ** 3
    assert vol_part < vol_full * 0.7, (vol_part, vol_full)


def test_scheduling_improves_coherence(rng):
    """Obs. 1 proxy: Morton scheduling raises adjacent-query cell sharing."""
    pts = kitti_like_cloud(5000, seed=1)
    qs = kitti_like_cloud(4000, seed=2)
    rng.shuffle(qs)
    spec = choose_grid_spec(pts, radius=0.05)
    before = float(coherence_statistic(spec, jnp.asarray(qs)))
    perm, _ = schedule_queries(spec, jnp.asarray(qs))
    after = float(coherence_statistic(spec, jnp.asarray(qs)[perm]))
    assert after > max(5 * before, before + 0.1), (before, after)


def test_skip_sphere_test_is_correct_not_just_fast(rng):
    """Range-search skip-test (section 5.1): candidates inside an
    r-inscribed megacell are within r by construction."""
    pts = rng.random((5000, 3)).astype(np.float32)
    qs = rng.random((500, 3)).astype(np.float32)
    r = 0.25
    params = SearchParams(radius=r, k=8, mode="range")
    # bundling may legitimately merge skip/no-skip partitions (cost-model
    # choice); disable it so the skip-test path itself is exercised
    ns = NeighborSearch(pts, params, SearchOpts(bundle=False))
    res = ns.query(qs)
    skip_bundles = [b for b in ns.report.bundles if b.skip_test]
    assert skip_bundles, "expected at least one skip-test bundle"
    d = np.asarray(res.distances2)
    assert (d[np.isfinite(d)] <= r * r + 1e-6).all()


def test_build_time_linear_proxy(rng):
    """Fig. 15 proxy: grid build work is O(N) — measured as the structure
    size actually written, which scales linearly in points."""
    for n in (1000, 2000, 4000):
        pts = rng.random((n, 3)).astype(np.float32)
        spec = choose_grid_spec(pts, radius=0.1)
        grid = build_cell_grid(jnp.asarray(pts), spec)
        assert int(grid.counts.sum()) == n
