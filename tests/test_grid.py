import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.grid import box_count, build_cell_grid, choose_grid_spec


def _points(rng, n):
    return rng.random((n, 3)).astype(np.float32)


def test_build_no_overflow_with_planned_capacity(rng):
    pts = _points(rng, 2000)
    spec = choose_grid_spec(pts, radius=0.1)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    assert int(grid.overflow) == 0
    assert int(grid.counts.sum()) == 2000


def test_every_point_in_its_cell(rng):
    pts = _points(rng, 500)
    spec = choose_grid_spec(pts, radius=0.15)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    dense = np.asarray(grid.dense)
    ccoord = np.asarray(spec.cell_of(jnp.asarray(pts)))
    for idx in range(0, 500, 37):
        cx, cy, cz = ccoord[idx]
        assert idx in dense[cx, cy, cz], (idx, ccoord[idx])


@given(st.integers(10, 400), st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=15)
def test_sat_box_count_matches_brute(n, seed):
    rng = np.random.default_rng(seed)
    pts = _points(rng, n)
    spec = choose_grid_spec(pts, radius=0.2)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    ccoord = np.asarray(spec.cell_of(jnp.asarray(pts)))
    lo = jnp.asarray([[1, 1, 1]], jnp.int32)
    hi = jnp.asarray([[3, 2, 4]], jnp.int32)
    got = int(box_count(grid.sat, lo, hi)[0])
    want = int(np.sum(np.all((ccoord >= [1, 1, 1]) & (ccoord <= [3, 2, 4]),
                             axis=1)))
    assert got == want


def test_capacity_overflow_reported(rng):
    pts = np.zeros((50, 3), np.float32)  # all in one cell
    spec = choose_grid_spec(pts, radius=0.1, capacity=8)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    assert int(grid.overflow) == 42
    assert int(grid.counts.max()) == 8


def _assert_spec_sane(spec, radius):
    assert spec.cell_size > 0 and np.isfinite(spec.cell_size)
    assert all(isinstance(d, int) and 0 < d < 64 for d in spec.dims)
    assert all(np.isfinite(o) for o in spec.origin)
    # the full-radius window must fit: extent was clamped to >= radius
    assert all(d * spec.cell_size >= radius for d in spec.dims)


def test_degenerate_extent_identical_points(rng):
    """Regression: a zero-extent bbox (all points identical) must not
    produce zero-size cells, NaN/degenerate dims, or wrong results —
    the extent clamps to ``radius`` per axis."""
    from repro.core import neighbor_search
    from repro.kernels.ref import brute_force_search

    pts = np.full((40, 3), 0.25, np.float32)
    spec = choose_grid_spec(pts, radius=0.05)
    _assert_spec_sane(spec, 0.05)
    res = neighbor_search(pts, pts[:7], 0.05, 8, mode="knn")
    _oi, _od, oc = brute_force_search(jnp.asarray(pts),
                                      jnp.asarray(pts[:7]), 0.05, 8)
    np.testing.assert_array_equal(np.asarray(oc), np.asarray(res.counts))
    np.testing.assert_allclose(np.asarray(res.distances2), 0.0, atol=1e-6)


def test_degenerate_extent_coplanar_points(rng):
    """Regression: one zero-extent axis (coplanar set) — dims stay finite
    and small on the flat axis and the search stays oracle-exact."""
    from repro.core import neighbor_search
    from repro.kernels.ref import brute_force_search

    pts = rng.random((300, 3)).astype(np.float32)
    pts[:, 2] = 0.4                              # flat in z
    r, k = 0.08, 8
    spec = choose_grid_spec(pts, radius=r)
    _assert_spec_sane(spec, r)
    qs = pts[::5]
    res = neighbor_search(pts, qs, r, k, mode="knn", knn_window="exact")
    _oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(qs),
                                     r, k)
    d_ref = np.where(np.isinf(np.asarray(od)), -1.0, np.asarray(od))
    d_got = np.where(np.isinf(np.asarray(res.distances2)), -1.0,
                     np.asarray(res.distances2))
    np.testing.assert_allclose(d_got, d_ref, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(oc), np.asarray(res.counts))
