import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.grid import box_count, build_cell_grid, choose_grid_spec


def _points(rng, n):
    return rng.random((n, 3)).astype(np.float32)


def test_build_no_overflow_with_planned_capacity(rng):
    pts = _points(rng, 2000)
    spec = choose_grid_spec(pts, radius=0.1)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    assert int(grid.overflow) == 0
    assert int(grid.counts.sum()) == 2000


def test_every_point_in_its_cell(rng):
    pts = _points(rng, 500)
    spec = choose_grid_spec(pts, radius=0.15)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    dense = np.asarray(grid.dense)
    ccoord = np.asarray(spec.cell_of(jnp.asarray(pts)))
    for idx in range(0, 500, 37):
        cx, cy, cz = ccoord[idx]
        assert idx in dense[cx, cy, cz], (idx, ccoord[idx])


@given(st.integers(10, 400), st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=15)
def test_sat_box_count_matches_brute(n, seed):
    rng = np.random.default_rng(seed)
    pts = _points(rng, n)
    spec = choose_grid_spec(pts, radius=0.2)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    ccoord = np.asarray(spec.cell_of(jnp.asarray(pts)))
    lo = jnp.asarray([[1, 1, 1]], jnp.int32)
    hi = jnp.asarray([[3, 2, 4]], jnp.int32)
    got = int(box_count(grid.sat, lo, hi)[0])
    want = int(np.sum(np.all((ccoord >= [1, 1, 1]) & (ccoord <= [3, 2, 4]),
                             axis=1)))
    assert got == want


def test_capacity_overflow_reported(rng):
    pts = np.zeros((50, 3), np.float32)  # all in one cell
    spec = choose_grid_spec(pts, radius=0.1, capacity=8)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    assert int(grid.overflow) == 42
    assert int(grid.counts.max()) == 8
