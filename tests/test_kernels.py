"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode on CPU; deliverable (c) requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.distance_tile import distance_tile
from repro.kernels.knn_tile import knn_tile, knn_tile_anchored
from repro.kernels.range_tile import range_count
from repro.kernels.ref import (brute_force_search, pairwise_d2,
                               range_count_ref, topk_select)


@pytest.mark.parametrize("nq,npts", [(8, 16), (100, 300), (256, 512),
                                     (33, 700), (513, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distance_tile_sweep(rng, nq, npts, dtype):
    q = jnp.asarray(rng.random((nq, 3)), dtype)
    p = jnp.asarray(rng.random((npts, 3)), dtype)
    ref = pairwise_d2(q.astype(jnp.float32), p.astype(jnp.float32))
    got = distance_tile(q, p, tq=32, tp=128)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=tol)


@pytest.mark.parametrize("k", [1, 4, 8, 32])
@pytest.mark.parametrize("m", [60, 256, 1000])
def test_knn_tile_sweep(rng, k, m):
    tq = 64
    q = jnp.asarray(rng.random((128, 3)), jnp.float32)
    p = jnp.asarray(rng.random((m, 3)), jnp.float32)
    wnd_idx = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (2, m))
    r = 0.4
    d2, idx = knn_tile(q, p, wnd_idx, k=k, r2=r * r, tq=tq, tm=128)
    oi, od, oc = brute_force_search(p, q, r, k)
    np.testing.assert_allclose(
        np.where(np.isinf(np.asarray(d2)), -1, np.asarray(d2)),
        np.where(np.isinf(np.asarray(od)), -1, np.asarray(od)), atol=1e-5)
    # indices agree where distances are distinct; always verify by distance
    recompute = np.sum(
        (np.asarray(q)[:, None] - np.asarray(p)[np.clip(np.asarray(idx), 0,
                                                        None)]) ** 2, -1)
    valid = np.asarray(idx) >= 0
    np.testing.assert_allclose(recompute[valid],
                               np.asarray(d2)[valid], atol=1e-5)


def test_knn_tile_k_exceeds_candidates(rng):
    q = jnp.asarray(rng.random((64, 3)), jnp.float32)
    p = jnp.asarray(rng.random((5, 3)), jnp.float32)
    wnd_idx = jnp.broadcast_to(jnp.arange(5, dtype=jnp.int32), (1, 5))
    d2, idx = knn_tile(q, p, wnd_idx, k=8, r2=10.0, tq=64, tm=128)
    assert (np.asarray(idx)[:, 5:] == -1).all()
    assert np.isinf(np.asarray(d2)[:, 5:]).all()


def test_knn_tile_all_masked(rng):
    q = jnp.asarray(rng.random((64, 3)), jnp.float32)
    p = jnp.ones((64, 3), jnp.float32) * 50.0
    wnd_idx = jnp.full((1, 64), -1, jnp.int32)
    d2, idx = knn_tile(q, p, wnd_idx, k=4, r2=0.01, tq=64, tm=64)
    assert (np.asarray(idx) == -1).all()


def test_knn_tile_duplicate_points(rng):
    q = jnp.zeros((64, 3), jnp.float32)
    p = jnp.zeros((10, 3), jnp.float32)  # all identical at the query
    wnd_idx = jnp.broadcast_to(jnp.arange(10, dtype=jnp.int32), (1, 10))
    d2, idx = knn_tile(q, p, wnd_idx, k=4, r2=1.0, tq=64, tm=128)
    assert np.allclose(np.asarray(d2), 0.0)
    assert len(set(np.asarray(idx)[0].tolist())) == 4  # distinct indices


@pytest.mark.parametrize("k", [1, 5, 8, 100])
def test_knn_tile_lane_padded_k(rng, k):
    """Non-multiple-of-128 K values: the output/scratch blocks are padded
    to the lane width inside the wrapper and sliced back, so results are
    identical to the logical-K contract (the TPU-lowering satellite; the
    same code path runs in interpret mode here)."""
    from repro.kernels.knn_tile import _pad_lane
    assert _pad_lane(1) == 128 and _pad_lane(128) == 128
    assert _pad_lane(129) == 256
    q = jnp.asarray(rng.random((128, 3)), jnp.float32)
    p = jnp.asarray(rng.random((400, 3)), jnp.float32)
    wnd_idx = jnp.broadcast_to(jnp.arange(400, dtype=jnp.int32), (2, 400))
    r = 0.5
    d2, idx = knn_tile(q, p, wnd_idx, k=k, r2=r * r, tq=64, tm=192)
    assert d2.shape == (128, k) and idx.shape == (128, k)
    oi, od, oc = brute_force_search(p, q, r, k)
    np.testing.assert_allclose(
        np.where(np.isinf(np.asarray(d2)), -1, np.asarray(d2)),
        np.where(np.isinf(np.asarray(od)), -1, np.asarray(od)), atol=1e-5)


def test_knn_tile_anchored_lane_padded_k(rng):
    """The anchored kernel under an odd K and a non-lane TM request: the
    wrapper rounds TM up and pads K; outputs match the id-stream kernel
    fed the identical candidates."""
    pts, spec, grid = _grid_fixture(rng)
    qs = jnp.asarray(rng.random((64, 3)), jnp.float32)
    dense_flat = grid.dense.reshape(-1)
    d2a, idxa = knn_tile_anchored(
        qs, jnp.asarray(pts), dense_flat, jnp.zeros((1, 3), jnp.int32),
        jnp.zeros((1,), jnp.int32), level=0, ws=spec.dims, dims=spec.dims,
        cap=spec.capacity, k=5, r2=0.15 ** 2, tq=64, tm=200)
    d2b, idxb = knn_tile(qs, jnp.asarray(pts), dense_flat[None, :], k=5,
                         r2=0.15 ** 2, tq=64)
    assert d2a.shape == (64, 5)
    np.testing.assert_array_equal(np.asarray(d2a), np.asarray(d2b))
    np.testing.assert_array_equal(np.asarray(idxa), np.asarray(idxb))


def _grid_fixture(rng, n=500, r=0.15):
    from repro.core.grid import build_cell_grid, choose_grid_spec
    pts = rng.random((n, 3)).astype(np.float32)
    spec = choose_grid_spec(pts, r)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    return pts, spec, grid


def test_knn_tile_anchored_matches_id_stream_kernel(rng):
    """The anchored scalar-prefetch kernel over the whole grid must match
    knn_tile fed the identical flattened candidate-id stream bitwise: the
    in-kernel window gather is pure index arithmetic on the same data."""
    pts, spec, grid = _grid_fixture(rng)
    qs = jnp.asarray(rng.random((64, 3)), jnp.float32)
    dense_flat = grid.dense.reshape(-1)
    d2a, idxa = knn_tile_anchored(
        qs, jnp.asarray(pts), dense_flat, jnp.zeros((1, 3), jnp.int32),
        jnp.zeros((1,), jnp.int32), level=0, ws=spec.dims, dims=spec.dims,
        cap=spec.capacity, k=4, r2=0.15 ** 2, tq=64)
    d2b, idxb = knn_tile(qs, jnp.asarray(pts), dense_flat[None, :], k=4,
                         r2=0.15 ** 2, tq=64)
    np.testing.assert_array_equal(np.asarray(d2a), np.asarray(d2b))
    np.testing.assert_array_equal(np.asarray(idxa), np.asarray(idxb))


def test_knn_tile_anchored_level_masking(rng):
    """Off-level tiles are predicated off inside the kernel and emit
    neutral rows — the masked per-level launch of the segmented schedule."""
    pts, spec, grid = _grid_fixture(rng)
    qs = jnp.asarray(rng.random((128, 3)), jnp.float32)
    dense_flat = grid.dense.reshape(-1)
    anchors = jnp.zeros((2, 3), jnp.int32)
    levels = jnp.asarray([0, 1], jnp.int32)
    d2, idx = knn_tile_anchored(
        qs, jnp.asarray(pts), dense_flat, anchors, levels, level=0,
        ws=spec.dims, dims=spec.dims, cap=spec.capacity, k=4, r2=0.15 ** 2,
        tq=64)
    assert (np.asarray(idx)[64:] == -1).all()       # masked tile: neutral
    assert np.isinf(np.asarray(d2)[64:]).all()
    assert (np.asarray(idx)[:64] >= 0).any()        # live tile: real rows


def test_knn_tile_anchored_skip_test_wired(rng):
    """The sphere-test skip is honored by the fused kernel (no silent
    skip_test=False): with a window that holds >= k in-sphere points the
    skip path returns the identical top-k, and the flag demonstrably
    changes behavior when the precondition is violated (out-of-radius
    candidates survive only under skip)."""
    pts, spec, grid = _grid_fixture(rng, n=800, r=0.3)
    qs = jnp.asarray(rng.random((64, 3)) * 0.2 + 0.4, jnp.float32)
    dense_flat = grid.dense.reshape(-1)
    kw = dict(level=0, ws=spec.dims, dims=spec.dims, cap=spec.capacity,
              k=4, tq=64)
    anchors = jnp.zeros((1, 3), jnp.int32)
    levels = jnp.zeros((1,), jnp.int32)
    args = (qs, jnp.asarray(pts), dense_flat, anchors, levels)
    d2_f, idx_f = knn_tile_anchored(*args, r2=0.3 ** 2, skip_test=False,
                                    **kw)
    d2_s, idx_s = knn_tile_anchored(*args, r2=0.3 ** 2, skip_test=True,
                                    **kw)
    # dense interior queries: >= k candidates within r, so eliding the r^2
    # filter must not change the streamed top-k
    np.testing.assert_array_equal(np.asarray(d2_f), np.asarray(d2_s))
    np.testing.assert_array_equal(np.asarray(idx_f), np.asarray(idx_s))
    # tiny radius: the filter empties the result, the skip keeps top-k
    d2_f2, _ = knn_tile_anchored(*args, r2=1e-8, skip_test=False, **kw)
    d2_s2, _ = knn_tile_anchored(*args, r2=1e-8, skip_test=True, **kw)
    assert np.isinf(np.asarray(d2_f2)).all()
    assert np.isfinite(np.asarray(d2_s2)).any()


@pytest.mark.parametrize("m,tm", [(100, 128), (600, 256)])
def test_range_count_sweep(rng, m, tm):
    q = jnp.asarray(rng.random((128, 3)), jnp.float32)
    p = jnp.asarray(rng.random((m, 3)), jnp.float32)
    wnd_pos = jnp.broadcast_to(p, (2, m, 3))
    wnd_idx = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (2, m))
    r = 0.25
    cnt = range_count(q, wnd_pos, wnd_idx, r2=r * r, tq=64, tm=tm)
    ref = range_count_ref(q, p, r)
    assert np.array_equal(np.asarray(cnt), np.asarray(ref))


@given(st.integers(1, 12), st.integers(2, 40), st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=15)
def test_topk_select_property(k, m, seed):
    rng = np.random.default_rng(seed)
    d2 = jnp.asarray(rng.random((4, m)), jnp.float32)
    idx = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (4, m))
    dk, ik = topk_select(d2, idx, k)
    ref = np.sort(np.asarray(d2), axis=1)[:, :k]
    want = np.pad(ref, ((0, 0), (0, max(k - m, 0))),
                  constant_values=np.inf)[:, :k]
    np.testing.assert_allclose(
        np.where(np.isinf(np.asarray(dk)), -1, np.asarray(dk)),
        np.where(np.isinf(want), -1, want), atol=1e-6)


@pytest.mark.parametrize("b,s,h,hd", [(2, 17, 3, 8), (1, 64, 2, 16)])
def test_rwkv_scan_kernel_matches_oracle(rng, b, s, h, hd):
    from repro.kernels.rwkv_scan import rwkv_scan
    from repro.models.layers import _rwkv_scan_core
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, hd))).clip(0, 5))
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    s0 = jax.random.normal(ks[5], (b, h, hd, hd)) * 0.3
    out_k, st_k = rwkv_scan(r, k, v, w, u, s0)
    out_r, st_r = _rwkv_scan_core(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               atol=1e-4)
