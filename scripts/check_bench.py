#!/usr/bin/env python
"""CI regression gate for the A/B benchmarks (scripts/ci.sh).

Compares each freshly-written ``BENCH_*.json`` against its committed
baseline (``git show HEAD:BENCH_*.json``) and FAILS if the new path
regressed by more than the tolerance on any case present in both. Gated
files (every path passed on the command line): ``BENCH_batch.json``
(vmapped multi-scene batching), ``BENCH_dynamic.json`` (session vs
rebuild-per-frame), ``BENCH_shard.json`` (sharded vs single-device
session), and ``BENCH_serve.json`` (micro-batched service vs sequential
per-request calls).

The gated statistic is each row's *speedup ratio* (old path / new path),
not absolute wall time: the ratio cancels machine speed, so the gate is
meaningful on shared CI hardware where absolute timings swing far more
than any real regression. Two further rules keep the gate honest:

* **like-against-like** — rows carry a provenance stamp (jax version,
  backend, device count; ``benchmarks/common.provenance``). A case whose
  baseline was measured under a different backend or device count is
  SKIPPED, not gated: such a ratio shift is an environment change, not a
  code regression. Un-stamped baselines (pre-provenance history) gate as
  before.
* **metric-delta table** — every shared numeric metric of each case (the
  unified schema the fig_* modules emit) is printed as an old/new/delta%
  table per figure, so a gate verdict always comes with the full context
  of what moved.

Knobs:

  REPRO_BENCH_TOL    fractional regression tolerance (default 0.10)
  REPRO_BENCH_GATE   0 disables the gate (always exit 0)

Usage: python scripts/check_bench.py [BENCH_batch.json ...]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

TOL = float(os.environ.get("REPRO_BENCH_TOL", "0.10"))
GATE = os.environ.get("REPRO_BENCH_GATE", "1") != "0"
METRIC = "speedup"

# per-file tolerance multipliers: the sharded benchmark's multi-slab rows
# time-slice N forced host devices on one physical CPU, and the dynamic
# smoke row's rebuild arm is compile-bound — both ratios are inherently
# noisier than the batch file's — gate them, but at a wider band so
# scheduler/compile jitter does not read as regression. The serve ratio
# divides two whole-burst wall times (host thread scheduling on both
# sides), so it too gets a wider band.
_TOL_SCALE = {"BENCH_shard.json": 2.0, "BENCH_dynamic.json": 1.5,
              "BENCH_serve.json": 1.5}


def _baseline(path: str) -> dict | None:
    """Committed baseline, or None with a printed reason (the gate fails
    open on environments without git history — a tarball export cannot be
    gated — but says so loudly instead of silently passing)."""
    cwd = os.path.dirname(os.path.abspath(path)) or "."
    try:
        subprocess.run(["git", "rev-parse", "HEAD"], capture_output=True,
                       check=True, cwd=cwd)
    except (subprocess.CalledProcessError, FileNotFoundError):
        print("check_bench: WARNING — no git history here; the regression "
              "gate cannot run (baseline lives in HEAD)")
        return None
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{os.path.basename(path)}"],
            capture_output=True, text=True, check=True, cwd=cwd).stdout
        return json.loads(blob)
    except subprocess.CalledProcessError:
        print(f"check_bench: {os.path.basename(path)} not committed at "
              "HEAD — nothing to gate against yet")
        return None
    except json.JSONDecodeError:
        print("check_bench: WARNING — committed baseline is not valid "
              "JSON; skipping")
        return None


# provenance keys whose mismatch invalidates a ratio comparison (the jax
# version is stamped for the trajectory record but does not skip the gate:
# ratios are expected to survive library upgrades, and silently un-gating
# every version bump would blind CI)
_PROV_GATE_KEYS = ("backend", "device_count")


def _prov_mismatch(cur_row: dict, base_row: dict) -> list[str]:
    """Provenance keys that differ — [] gates; non-empty skips the case.
    Un-stamped rows (either side) compare as matching for back-compat with
    pre-provenance baselines."""
    cp, bp = cur_row.get("provenance"), base_row.get("provenance")
    if not isinstance(cp, dict) or not isinstance(bp, dict):
        return []
    return [k for k in _PROV_GATE_KEYS if cp.get(k) != bp.get(k)]


def _metric_rows(case: str, cur_row: dict, base_row: dict) -> list[tuple]:
    """(case, metric, old, new, delta%) for every shared numeric metric."""
    rows = []
    for k in sorted(set(cur_row) & set(base_row)):
        cv, bv = cur_row[k], base_row[k]
        if isinstance(cv, bool) or isinstance(bv, bool):
            continue
        if not isinstance(cv, (int, float)) or not isinstance(bv,
                                                              (int, float)):
            continue
        delta = ((float(cv) - float(bv)) / float(bv) * 100.0 if bv
                 else (0.0 if not cv else float("inf")))
        rows.append((case, k, float(bv), float(cv), delta))
    return rows


def _gate_one(path: str) -> int:
    """Gate one BENCH file; returns the number of regressed cases (or a
    synthetic 1 when the fresh file is missing entirely)."""
    if not os.path.exists(path):
        print(f"check_bench: {path} missing — run the matching "
              "`benchmarks.run` figure first")
        return 1
    with open(path) as f:
        current = json.load(f)
    base = _baseline(path)
    if base is None:
        return 0
    shared = sorted(set(current) & set(base))
    if not shared:
        print(f"check_bench: {os.path.basename(path)}: no overlapping "
              "cases with the baseline — skipping (commit the smoke row "
              "to enable the gate)")
        return 0
    name = os.path.basename(path)
    tol = TOL * _TOL_SCALE.get(name, 1.0)
    failures, gated = [], 0
    table: list[tuple] = []
    verdicts: dict = {}
    for case in shared:
        cur_row, base_row = current[case], base[case]
        diffs = _prov_mismatch(cur_row, base_row)
        if diffs:
            cp = cur_row.get("provenance", {})
            bp = base_row.get("provenance", {})
            detail = ", ".join(f"{k}: {bp.get(k)} -> {cp.get(k)}"
                               for k in diffs)
            print(f"check_bench: {name}: {case}: SKIPPED — baseline "
                  f"provenance differs ({detail}); not like-against-like")
            continue
        gated += 1
        table.extend(_metric_rows(case, cur_row, base_row))
        new = float(cur_row.get(METRIC, 0.0))
        old = float(base_row.get(METRIC, 0.0))
        if old > 0 and new < old * (1.0 - tol):
            verdicts[case] = "REGRESSED"
            failures.append(case)
        else:
            verdicts[case] = "ok"
    if table:
        case_w = max(len(r[0]) for r in table) + 2
        met_w = max(len(r[1]) for r in table) + 2
        print(f"# ---- {name}: metric deltas vs committed baseline ----")
        print(f"# {'case':<{case_w}}{'metric':<{met_w}}{'old':>12}"
              f"{'new':>12}{'delta':>9}")
        for case, metric, old, new, delta in table:
            mark = (f" [{verdicts[case]}]" if metric == METRIC else "")
            print(f"# {case:<{case_w}}{metric:<{met_w}}{old:>12.3f}"
                  f"{new:>12.3f}{delta:>+8.1f}%{mark}")
    if failures:
        print(f"check_bench: FAIL — {name}: {len(failures)} case(s) "
              f"regressed >{tol:.0%} vs committed baseline: "
              f"{', '.join(failures)}")
    elif gated:
        print(f"check_bench: {name}: OK ({gated} case(s) within {tol:.0%})")
    else:
        print(f"check_bench: {name}: no like-against-like cases to gate")
    return len(failures)


def main() -> int:
    paths = sys.argv[1:] or ["BENCH_batch.json"]
    if not GATE:
        print("check_bench: gate disabled (REPRO_BENCH_GATE=0)")
        return 0
    bad = sum(_gate_one(p) for p in paths)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
