#!/usr/bin/env python
"""CI regression gate for the A/B benchmarks (scripts/ci.sh).

Compares each freshly-written ``BENCH_*.json`` against its committed
baseline (``git show HEAD:BENCH_*.json``) and FAILS if the new path
regressed by more than the tolerance on any case present in both. Gated
files (every path passed on the command line): ``BENCH_batch.json``
(vmapped multi-scene batching), ``BENCH_dynamic.json`` (session vs
rebuild-per-frame), and ``BENCH_shard.json`` (sharded vs single-device
session).

The gated statistic is each row's *speedup ratio* (old path / new path),
not absolute wall time: the ratio cancels machine speed, so the gate is
meaningful on shared CI hardware where absolute timings swing far more
than any real regression. Knobs:

  REPRO_BENCH_TOL    fractional regression tolerance (default 0.10)
  REPRO_BENCH_GATE   0 disables the gate (always exit 0)

Usage: python scripts/check_bench.py [BENCH_batch.json ...]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

TOL = float(os.environ.get("REPRO_BENCH_TOL", "0.10"))
GATE = os.environ.get("REPRO_BENCH_GATE", "1") != "0"
METRIC = "speedup"

# per-file tolerance multipliers: the sharded benchmark's multi-slab rows
# time-slice N forced host devices on one physical CPU, and the dynamic
# smoke row's rebuild arm is compile-bound — both ratios are inherently
# noisier than the batch file's — gate them, but at a wider band so
# scheduler/compile jitter does not read as regression
_TOL_SCALE = {"BENCH_shard.json": 2.0, "BENCH_dynamic.json": 1.5}


def _baseline(path: str) -> dict | None:
    """Committed baseline, or None with a printed reason (the gate fails
    open on environments without git history — a tarball export cannot be
    gated — but says so loudly instead of silently passing)."""
    cwd = os.path.dirname(os.path.abspath(path)) or "."
    try:
        subprocess.run(["git", "rev-parse", "HEAD"], capture_output=True,
                       check=True, cwd=cwd)
    except (subprocess.CalledProcessError, FileNotFoundError):
        print("check_bench: WARNING — no git history here; the regression "
              "gate cannot run (baseline lives in HEAD)")
        return None
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{os.path.basename(path)}"],
            capture_output=True, text=True, check=True, cwd=cwd).stdout
        return json.loads(blob)
    except subprocess.CalledProcessError:
        print(f"check_bench: {os.path.basename(path)} not committed at "
              "HEAD — nothing to gate against yet")
        return None
    except json.JSONDecodeError:
        print("check_bench: WARNING — committed baseline is not valid "
              "JSON; skipping")
        return None


def _gate_one(path: str) -> int:
    """Gate one BENCH file; returns the number of regressed cases (or a
    synthetic 1 when the fresh file is missing entirely)."""
    if not os.path.exists(path):
        print(f"check_bench: {path} missing — run the matching "
              "`benchmarks.run` figure first")
        return 1
    with open(path) as f:
        current = json.load(f)
    base = _baseline(path)
    if base is None:
        return 0
    shared = sorted(set(current) & set(base))
    if not shared:
        print(f"check_bench: {os.path.basename(path)}: no overlapping "
              "cases with the baseline — skipping (commit the smoke row "
              "to enable the gate)")
        return 0
    tol = TOL * _TOL_SCALE.get(os.path.basename(path), 1.0)
    failures = []
    for case in shared:
        new = float(current[case].get(METRIC, 0.0))
        old = float(base[case].get(METRIC, 0.0))
        verdict = "ok"
        if old > 0 and new < old * (1.0 - tol):
            verdict = "REGRESSED"
            failures.append(case)
        print(f"check_bench: {os.path.basename(path)}: {case}: {METRIC} "
              f"{old:.3f} -> {new:.3f} [{verdict}]")
    if failures:
        print(f"check_bench: FAIL — {os.path.basename(path)}: "
              f"{len(failures)} case(s) regressed >{tol:.0%} vs committed "
              f"baseline: {', '.join(failures)}")
    else:
        print(f"check_bench: {os.path.basename(path)}: OK "
              f"({len(shared)} case(s) within {tol:.0%})")
    return len(failures)


def main() -> int:
    paths = sys.argv[1:] or ["BENCH_batch.json"]
    if not GATE:
        print("check_bench: gate disabled (REPRO_BENCH_GATE=0)")
        return 0
    bad = sum(_gate_one(p) for p in paths)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
