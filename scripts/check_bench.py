#!/usr/bin/env python
"""CI regression gate for the vmapped batch benchmark (scripts/ci.sh).

Compares the freshly-written ``BENCH_batch.json`` against the committed
baseline (``git show HEAD:BENCH_batch.json``) and FAILS if the vmapped
path regressed by more than the tolerance on any case present in both.

The gated statistic is the *speedup ratio* (sequential / vmapped per
frame), not absolute wall time: the ratio cancels machine speed, so the
gate is meaningful on shared CI hardware where absolute timings swing far
more than any real regression. Knobs:

  REPRO_BENCH_TOL    fractional regression tolerance (default 0.10)
  REPRO_BENCH_GATE   0 disables the gate (always exit 0)

Usage: python scripts/check_bench.py [BENCH_batch.json]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

TOL = float(os.environ.get("REPRO_BENCH_TOL", "0.10"))
GATE = os.environ.get("REPRO_BENCH_GATE", "1") != "0"
METRIC = "speedup"


def _baseline(path: str) -> dict | None:
    """Committed baseline, or None with a printed reason (the gate fails
    open on environments without git history — a tarball export cannot be
    gated — but says so loudly instead of silently passing)."""
    cwd = os.path.dirname(os.path.abspath(path)) or "."
    try:
        subprocess.run(["git", "rev-parse", "HEAD"], capture_output=True,
                       check=True, cwd=cwd)
    except (subprocess.CalledProcessError, FileNotFoundError):
        print("check_bench: WARNING — no git history here; the regression "
              "gate cannot run (baseline lives in HEAD)")
        return None
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{os.path.basename(path)}"],
            capture_output=True, text=True, check=True, cwd=cwd).stdout
        return json.loads(blob)
    except subprocess.CalledProcessError:
        print(f"check_bench: {os.path.basename(path)} not committed at "
              "HEAD — nothing to gate against yet")
        return None
    except json.JSONDecodeError:
        print("check_bench: WARNING — committed baseline is not valid "
              "JSON; skipping")
        return None


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_batch.json"
    if not GATE:
        print("check_bench: gate disabled (REPRO_BENCH_GATE=0)")
        return 0
    if not os.path.exists(path):
        print(f"check_bench: {path} missing — run `benchmarks.run figbatch`")
        return 1
    with open(path) as f:
        current = json.load(f)
    base = _baseline(path)
    if base is None:
        return 0
    shared = sorted(set(current) & set(base))
    if not shared:
        print("check_bench: no overlapping cases with the baseline — "
              "skipping (commit the smoke row to enable the gate)")
        return 0
    failures = []
    for case in shared:
        new = float(current[case].get(METRIC, 0.0))
        old = float(base[case].get(METRIC, 0.0))
        verdict = "ok"
        if old > 0 and new < old * (1.0 - TOL):
            verdict = "REGRESSED"
            failures.append(case)
        print(f"check_bench: {case}: {METRIC} {old:.3f} -> {new:.3f} "
              f"[{verdict}]")
    if failures:
        print(f"check_bench: FAIL — {len(failures)} case(s) regressed "
              f">{TOL:.0%} vs committed baseline: {', '.join(failures)}")
        return 1
    print(f"check_bench: OK ({len(shared)} case(s) within {TOL:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
