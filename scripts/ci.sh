#!/usr/bin/env bash
# One-command local/CI gate: tier-1 tests + executor smoke benchmark.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

# smoke the executor benchmark (shrunken workloads; asserts the executor
# path is oracle-identical to the host loop and writes BENCH_executor.json)
REPRO_BENCH_SMOKE=1 python -m benchmarks.run figtp

echo "ci.sh: OK"
