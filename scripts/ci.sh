#!/usr/bin/env bash
# One-command local/CI gate: tier-1 tests + executor smoke benchmark.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# main sweep minus the mesh suite, which gets its own invocation below
# (running it in both would double the slowest part of CI)
python -m pytest -x -q --ignore=tests/test_multidevice.py

# the public-API snapshot gate on its own (fast, fails loud when repro.api
# exports change without a CHANGES.md note — see tests/test_api.py)
python -m pytest -x -q tests/test_api.py::test_public_api_snapshot

# telemetry-on smoke: the tier-1 suite once with span recording enabled
# (REPRO_TRACE=1, DESIGN.md section 9) so host-side telemetry can never
# change results or break the one-sync/caching contracts unnoticed
REPRO_TRACE=1 python -m pytest -x -q --ignore=tests/test_multidevice.py

# validation-on smoke: the tier-1 suite once with input validation armed
# (REPRO_VALIDATE=1, DESIGN.md section 11) — validation is host-side
# pre-upload only, so jaxprs, results, and sync counts must be identical
REPRO_VALIDATE=1 python -m pytest -x -q --ignore=tests/test_multidevice.py

# the mesh paths (sharded sessions, distributed routing, shard_map
# composition) under 8 forced host devices so they execute on CPU CI even
# when the default device count is 1 (the tests also re-exec themselves in
# subprocesses with this env; setting it here makes the requirement
# visible and keeps any future in-process mesh test working)
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m pytest -x -q tests/test_multidevice.py

# smoke the executor benchmark (shrunken workloads; asserts the executor
# path is oracle-identical to the host loop and writes BENCH_executor.json)
REPRO_BENCH_SMOKE=1 python -m benchmarks.run figtp

# smoke the multi-scene batching, dynamic-session, sharded-session, and
# serving benchmarks (each asserts exactness between its two paths and
# merge-accumulates its BENCH_*.json)
REPRO_BENCH_SMOKE=1 python -m benchmarks.run figbatch
REPRO_BENCH_SMOKE=1 python -m benchmarks.run figdyn
REPRO_BENCH_SMOKE=1 python -m benchmarks.run figshard
REPRO_BENCH_SMOKE=1 python -m benchmarks.run figserve

# gate: fail if any tracked speedup ratio regressed >10% vs the committed
# baseline (ratio-gated so machine speed cancels; scripts/check_bench.py)
python scripts/check_bench.py BENCH_batch.json BENCH_dynamic.json \
    BENCH_shard.json BENCH_serve.json

# smoke the multi-tenant serving CLI (synthetic trace through the
# admission queue / micro-batcher), plus once with span recording on so
# the serve telemetry path cannot change results unnoticed
python -m repro.launch.serve --smoke
REPRO_TRACE=1 python -m repro.launch.serve --smoke

# seeded chaos smoke (DESIGN.md section 11): the same serve trace under
# deterministic fault injection — 20% launch failures, 10% stragglers —
# must account every request to one taxonomy outcome with ZERO hung
# futures (the driver exits nonzero on any stranded future). Flight
# recording is on so the chaos path exercises the event ring too.
REPRO_FLIGHT=1 REPRO_FLIGHT_PATH=/tmp/repro_flight_chaos.json \
    REPRO_FAULTS=launch:0.2,straggler:0.1 \
    python -m repro.launch.serve --trace short

# flight-recorder gate (DESIGN.md section 12): force scene0's breaker
# open (launch faults scoped to scene0 at p=1.0 exhaust the retry budget
# every batch) and require a parseable post-mortem dump with a
# breaker_open reason — the breaker-trip path must produce evidence.
# Every request still resolves (CircuitOpen is a taxonomy outcome), so
# the driver itself exits 0; REPRO_SLO stays unset so the SLO gate is
# not armed against the forced failures.
REPRO_FLIGHT=1 REPRO_FLIGHT_PATH=/tmp/repro_flight_ci.json \
    REPRO_FAULTS=launch:1.0,scene:scene0 \
    python -m repro.launch.serve --trace short
python - <<'PY'
import json
doc = json.load(open("/tmp/repro_flight_ci.json"))
assert doc["schema"] == "repro.obs/flight-v1", doc["schema"]
assert doc["reason"].startswith("breaker_open"), doc["reason"]
assert doc["events"], "flight dump has no events"
assert any(e["kind"] == "breaker_trip" for e in doc["events"]), \
    "no breaker_trip event in flight dump"
assert doc["metrics"]["metrics"], "flight dump has no metrics"
print("ci.sh: flight-recorder dump OK "
      f"({len(doc['events'])} events, reason {doc['reason']!r})")
PY

# obs_top smoke: the live dashboard renders frames over a real serving
# workload and the OpenMetrics scrape path runs end to end
python -m repro.launch.obs_top --demo --frames 2 --interval 0.5
python -m repro.launch.obs_top --openmetrics > /tmp/repro_openmetrics.txt
tail -1 /tmp/repro_openmetrics.txt | grep -q "# EOF"

# smoke the dynamic-scene session path: the SPH example on the session
# (and its legacy A/B flag), so the SimulationSession path cannot
# silently rot
python examples/sph_fluid.py --particles 500 --steps 2
python examples/sph_fluid.py --particles 500 --steps 2 --rebuild

echo "ci.sh: OK"
