#!/usr/bin/env bash
# One-command local/CI gate: tier-1 tests + executor smoke benchmark.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

# the public-API snapshot gate on its own (fast, fails loud when repro.api
# exports change without a CHANGES.md note — see tests/test_api.py)
python -m pytest -x -q tests/test_api.py::test_public_api_snapshot

# smoke the executor benchmark (shrunken workloads; asserts the executor
# path is oracle-identical to the host loop and writes BENCH_executor.json)
REPRO_BENCH_SMOKE=1 python -m benchmarks.run figtp

# smoke the multi-scene batching benchmark (vmapped functional query vs
# sequential sessions; asserts scene-by-scene equality, BENCH_batch.json),
# then gate: fail if the vmapped speedup regressed >10% vs the committed
# baseline (ratio-gated so machine speed cancels; see scripts/check_bench.py)
REPRO_BENCH_SMOKE=1 python -m benchmarks.run figbatch
python scripts/check_bench.py BENCH_batch.json

# smoke the dynamic-scene session path: the SPH example on the session
# (and its legacy A/B flag) + the session-vs-rebuild benchmark, so the
# SimulationSession path cannot silently rot
python examples/sph_fluid.py --particles 500 --steps 2
python examples/sph_fluid.py --particles 500 --steps 2 --rebuild
REPRO_BENCH_SMOKE=1 python -m benchmarks.run figdyn

echo "ci.sh: OK"
