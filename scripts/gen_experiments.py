"""Generate EXPERIMENTS.md sections Dry-run and Roofline from the dry-run
JSONs (before = experiments/dryrun_v0_baseline, after = experiments/dryrun).
Section Perf's hillclimb log is maintained by hand in
experiments/PERF_LOG.md and inlined verbatim.

  PYTHONPATH=src python scripts/gen_experiments.py
"""
import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
AFTER = os.path.join(ROOT, "experiments", "dryrun")
BEFORE = os.path.join(ROOT, "experiments", "dryrun_v0_baseline")
MID = os.path.join(ROOT, "experiments", "dryrun_v1_iter5")
PERF_LOG = os.path.join(ROOT, "experiments", "PERF_LOG.md")


def load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        out[(r["mesh"], r["arch"], r["shape"])] = r
    return out


def gb(x):
    return f"{x / 1e9:.2f}"


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.3g}us"
    if x < 1:
        return f"{x * 1e3:.3g}ms"
    return f"{x:.3g}s"


def roofline_frac(t):
    peak = max(t["compute_s"], t["memory_s"], t["collective_s"])
    return t["compute_s"] / peak if peak > 0 else 0.0


def bottleneck_note(r):
    t = r["roofline"]
    dom = t["dominant"]
    kind = "train" if r["shape"].startswith("train") else (
        "prefill" if r["shape"].startswith("prefill") else "decode")
    arch = r["arch"]
    if dom == "collective":
        if kind == "train":
            return ("gradient all-reduce + FSDP all-gathers dominate: more "
                    "compute/comm overlap (bucketing) and larger per-device "
                    "batch move it down")
        if r.get("meta", {}).get("param_profile") == "train":
            return ("weights exceed the serving-replication HBM budget, so "
                    "per-token FSDP weight gathers remain: int8/fp8 weights "
                    "would enable the serve profile")
        return ("within-group TP all-reduces of [B,1,d] activations remain: "
                "fusing the two per-layer all-reduces halves it")
    if dom == "memory":
        if arch.startswith("rwkv") and kind == "train":
            return ("recurrent-state HBM traffic: larger RWKV chunk or the "
                    "VMEM-resident Pallas scan removes the residual")
        if kind == "decode":
            return ("per-token weight + KV reads are irreducible at batch "
                    "1-per-replica: batching more requests per group "
                    "amortizes them")
        return ("activation traffic: wider fusion / flash-style attention "
                "tiles reduce HBM round-trips")
    return ("MXU-bound: this cell is at the compute roofline; only "
            "algorithmic work reduction helps")


def cell_table(recs, mesh):
    lines = [
        "| arch | shape | status | dominant | compute | memory | collective"
        " | roofline-frac | useful | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (m, a, s), r in sorted(recs.items()):
        if m != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {a} | {s} | skipped | — | — | — | — | — | — | "
                         f"{r['reason'][:90]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {a} | {s} | ERROR | — | — | — | — | — | — |"
                         f" {r.get('error', '')[:90]} |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {a} | {s} | ok | **{t['dominant']}** | "
            f"{fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} | "
            f"{fmt_s(t['collective_s'])} | {roofline_frac(t):.3f} | "
            f"{t['useful_flops_ratio']:.2f} | {bottleneck_note(r)} |")
    return "\n".join(lines)


def memory_table(recs):
    lines = [
        "| arch | shape | profile | static GB/dev | analytic peak GB/dev |"
        " fits 16 GB |",
        "|---|---|---|---|---|---|",
    ]
    for (m, a, s), r in sorted(recs.items()):
        if m != "pod" or r["status"] != "ok":
            continue
        meta = r["meta"]
        peak = meta["analytic_peak_bytes"]
        lines.append(
            f"| {a} | {s} | {meta.get('param_profile', 'train')} | "
            f"{gb(meta['static_bytes_per_device'])} | {gb(peak)} | "
            f"{'yes' if peak < 16e9 else '**NO**'} |")
    return "\n".join(lines)


def before_after(before, mid, after):
    lines = [
        "| cell (pod mesh) | v0 baseline | v1 (iters 1-5) | final (6-7) |"
        " total |",
        "|---|---|---|---|---|",
    ]
    for key in sorted(after):
        if key[0] != "pod":
            continue
        b, m, a = before.get(key), mid.get(key), after[key]
        if not b or b["status"] != "ok" or a["status"] != "ok":
            continue
        tb, ta = b["roofline"], a["roofline"]
        tm = m["roofline"] if m and m["status"] == "ok" else None
        domb = max(tb["compute_s"], tb["memory_s"], tb["collective_s"])
        domm = (max(tm["compute_s"], tm["memory_s"], tm["collective_s"])
                if tm else None)
        doma = max(ta["compute_s"], ta["memory_s"], ta["collective_s"])
        if domb <= 0 or abs(doma / domb - 1) < 0.05:
            continue
        cell = "/".join(key[1:])
        lines.append(
            f"| {cell} | {fmt_s(domb)} | "
            f"{fmt_s(domm) if domm else '—'} | {fmt_s(doma)} | "
            f"**{domb / doma:.1f}x** |")
    return "\n".join(lines)


def main():
    after = load(AFTER)
    before = load(BEFORE)
    mid = load(MID)
    n_ok = sum(r["status"] == "ok" for r in after.values())
    n_skip = sum(r["status"] == "skipped" for r in after.values())

    perf_log = ""
    if os.path.exists(PERF_LOG):
        perf_log = open(PERF_LOG).read()

    doc = f"""# EXPERIMENTS

All artifacts regenerable: dry-run JSONs via
`python -m repro.launch.dryrun --all --mesh both`, benchmark CSV via
`python -m benchmarks.run` (bench_output.txt), tests via `pytest tests/`
(test_output.txt). Hardware model: TPU v5e-class — 197 TFLOP/s bf16,
819 GB/s HBM, 50 GB/s/link ICI (per brief).

## Methodology notes (read first)

* **Meshes.** pod = 16x16 (data x model, 256 chips); multipod = 2x16x16
  (pod x data x model, 512 chips; "pod" is pure DP). Both build from 512
  forced host devices; every cell is `jit(...).lower().compile()` with the
  production shardings — compile success is the multi-pod dry-run gate.
* **Trip-count-corrected costs.** XLA `cost_analysis()` counts `while`
  bodies once, so scanned layers / microbatch loops are invisible in the
  raw numbers. Each cell therefore also lowers a one-period layer probe
  under the same shardings, and the step cost is composed with the known
  static trip counts (dryrun.compose_costs). The CE chunk loop and the
  optimizer are added analytically. The rwkv inner time-scan body is
  counted once inside the probe (<2% of layer flops; documented
  undercount).
* **Collective bytes** are summed from the post-SPMD per-device HLO
  (result-shape heuristic per op; async -start counted once), then
  composed with the same trip counts.
* **Memory.** The CPU backend's `memory_analysis()` is recorded in the
  JSONs but includes layout copies a TPU build fuses away; the figure we
  stand behind is the exact sharded static footprint (params + opt/cache
  under the recorded PartitionSpecs) plus a remat-aware activation model
  (`analytic_peak_bytes`).
* **MODEL_FLOPS** = 6·N_active·D (train), 2·N_active·D (prefill per prompt
  token / decode per generated token). ``useful`` = MODEL_FLOPS /
  corrected-HLO-FLOPs; remat makes the healthy train ceiling ~0.75
  (4 passes executed vs 3 counted).

## Dry-run (deliverable e)

{n_ok} cells ok, {n_skip} skipped-with-reason, 0 errors, across both
meshes. Skips are structural per the brief: `long_500k` for the 8
non-sub-quadratic archs, whisper serve shapes beyond its 448-position
decoder.

### Per-device memory fit (pod mesh, 16 GB HBM)

{memory_table(after)}

deepseek-v3-671b train sits at the edge by design: bf16 params (5.2 GB) +
int8 sqrt-space Adam moments (5.3 GB) + remat activations; the multipod
mesh halves the param shards. grok/deepseek decode cells keep the FSDP
profile (weights too large to replicate per serving group) and pay the
documented collective price.

## Roofline (deliverable g) — single-pod mesh (16x16, 256 chips)

roofline-frac = compute_s / max(terms): 1.0 means compute-bound at the
hardware roofline.

{cell_table(after, "pod")}

### Multi-pod mesh (2x16x16, 512 chips)

{cell_table(after, "multipod")}

## Perf (hillclimb log: hypothesis -> change -> before -> after)

{perf_log}

### Auto-extracted before/after (step-bound = max roofline term; cells
that moved >= 5%)

{before_after(before, mid, after)}
"""
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(doc)
    print(f"EXPERIMENTS.md written: {n_ok} ok / {n_skip} skipped")


if __name__ == "__main__":
    main()
