"""RTNN-core hillclimb harness (EXPERIMENTS.md section Perf, the cell most
representative of the paper's own technique — measured live on this
backend, unlike the dry-run cells).

A/B variants, selected by env before process start:
  REPRO_SELECTION=sort|topk      candidate selection algorithm
and the paper's own ablation axes (schedule/partition/bundle) for context.

  PYTHONPATH=src REPRO_SELECTION=sort  python -m benchmarks.perf_search_hillclimb
  PYTHONPATH=src REPRO_SELECTION=topk  python -m benchmarks.perf_search_hillclimb
"""
import os

import numpy as np

from repro.core import NeighborSearch, SearchOpts, SearchParams
from repro.data.pointclouds import kitti_like_cloud, uniform_cloud
from .common import emit, timeit


def run():
    sel = os.environ.get("REPRO_SELECTION", "topk")
    for name, maker, n, nq, r, k in [
        ("kitti", kitti_like_cloud, 40_000, 10_000, 0.02, 8),
        ("scan", uniform_cloud, 30_000, 10_000, 0.03, 16),
    ]:
        pts = maker(n, seed=1)
        qs = maker(nq, seed=2)
        ns = NeighborSearch(pts, SearchParams(radius=r, k=k), SearchOpts())
        t = timeit(lambda: ns.query(qs), warmup=1, repeats=3)
        emit(f"perf/{name}/selection={sel}", t / nq,
             f"total={t:.2f}s;partitions={ns.report.num_partitions}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
