"""Serving throughput: signature-bucket micro-batching vs sequential
per-request calls at matched load (DESIGN.md section 10).

A seeded multi-tenant burst — >= 64 concurrent requests over >= 2 scenes,
mixed (radius, K) signatures, variable per-request query counts — is
served two ways against the SAME resident scenes:

* ``sequential``: one ``api.cached_searcher(...).query(...)`` per request
  in arrival order — the pre-serve baseline, one launch + one host sync
  per request;
* ``serve``: everything admitted into ``repro.serve.NeighborService`` and
  drained — few concatenated launches, one host sync per drained batch.

Both passes run with warm plan/compile caches (a warm-up burst pays the
compiles; the registry carries the warmed variants into the timed pass),
and the serve results are asserted bitwise-identical to the sequential
ones before anything is timed. Rows accumulate in ``BENCH_serve.json``;
``speedup`` = sequential_time / serve_time is the regression-gated metric
(acceptance floor: >= 1.3x at the 64-request mixed case).

``REPRO_BENCH_SMOKE=1`` shrinks scene sizes for CI (scripts/ci.sh).
"""
from __future__ import annotations

import os
import time

import numpy as np

import repro.api as api
from repro.core import SearchParams
from repro.serve import NeighborService, SceneRegistry, ServeOpts

from .common import emit, write_bench

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serve.json")

SIGNATURES = [
    SearchParams(radius=0.09, k=8, knn_window="exact"),
    SearchParams(radius=0.13, k=4, knn_window="exact"),
]


def _build_burst(n_scenes: int, n_points: int, n_requests: int, seed: int):
    """The concurrent request burst: (scene_id, params, queries) with a
    skewed tenant mix and variable request sizes."""
    rng = np.random.default_rng(seed)
    scenes = {f"scene{i}": rng.random((n_points, 3)).astype(np.float32)
              for i in range(n_scenes)}
    weights = np.array([1.0 / (i + 1) for i in range(n_scenes)])
    weights /= weights.sum()
    ids = list(scenes)
    burst = []
    for _ in range(n_requests):
        sid = ids[int(rng.choice(n_scenes, p=weights))]
        params = SIGNATURES[int(rng.integers(len(SIGNATURES)))]
        nq = int(rng.integers(8, 65))
        burst.append((sid, params,
                      rng.random((nq, 3)).astype(np.float32)))
    return scenes, burst


def _assert_identical(a, b):
    assert np.array_equal(np.asarray(a.indices), np.asarray(b.indices))
    assert np.array_equal(np.asarray(a.counts), np.asarray(b.counts))
    da = np.where(np.isinf(np.asarray(a.distances2)), -1.0,
                  np.asarray(a.distances2))
    db = np.where(np.isinf(np.asarray(b.distances2)), -1.0,
                  np.asarray(b.distances2))
    assert np.array_equal(da, db)


def _sequential_pass(scenes, burst):
    out = []
    for sid, params, q in burst:
        out.append(api.cached_searcher(scenes[sid], params).query(q))
    return out


def _serve_pass(registry, burst):
    """One burst through a fresh service over the (already-warm) shared
    registry: submit everything, drain, return (futures, reports, svc)."""
    svc = NeighborService(
        ServeOpts(max_batch=4096, max_pending=1 << 22, pipeline=1),
        registry=registry)
    futures = [svc.submit(sid, q, params, now=0.0)
               for sid, params, q in burst]
    reports = svc.drain()
    return futures, reports, svc


def run():
    if SMOKE:
        # distinct case name: the smoke row must not clobber the committed
        # full-run row under write_bench's merge-accumulate
        cases = [("mixed-2x64-smoke", 2, 1500, 64, 3)]
    else:
        cases = [
            ("mixed-2x64", 2, 6000, 64, 5),      # the acceptance gate case
            ("mixed-4x192", 4, 6000, 192, 3),    # more tenants, deeper burst
        ]
    results = {}
    for name, n_scenes, n_points, n_requests, repeats in cases:
        scenes, burst = _build_burst(n_scenes, n_points, n_requests,
                                     seed=11)
        n = len(burst)

        # -- warm both paths + parity gate (untimed) ------------------------
        api.searcher_cache_clear()
        refs = _sequential_pass(scenes, burst)
        registry = SceneRegistry(capacity=max(n_scenes, 2))
        svc0 = NeighborService(ServeOpts(max_batch=4096,
                                         max_pending=1 << 22),
                               registry=registry)
        for sid, pts in scenes.items():
            svc0.register_scene(sid, pts)
        futures, _, _ = _serve_pass(registry, burst)
        for fut, ref in zip(futures, refs):
            _assert_identical(fut.result(), ref)

        # -- timed: interleaved best-of at matched load ---------------------
        ts_seq, ts_srv = [], []
        last = None
        for _ in range(repeats):
            t0 = time.perf_counter()
            _sequential_pass(scenes, burst)
            ts_seq.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            last = _serve_pass(registry, burst)
            ts_srv.append(time.perf_counter() - t0)
        t_seq, t_srv = min(ts_seq), min(ts_srv)

        _, reports, svc = last
        st = svc.stats()
        lat = svc._metrics.snapshot().get("request_s", {})
        occ = (sum(r.nq for r in reports)
               / max(sum(r.pad_n for r in reports), 1))
        row = {
            "scenes": n_scenes,
            "requests": n,
            "sequential_us_per_req": t_seq / n * 1e6,
            "serve_us_per_req": t_srv / n * 1e6,
            "sequential_qps": n / t_seq,
            "serve_qps": n / t_srv,
            "speedup": t_seq / t_srv,
            "batches": int(st["batches"]),
            "host_syncs": int(st["host_syncs"]),
            "occupancy": occ,
            "p50_ms": lat.get("p50", 0.0) * 1e3,
            "p99_ms": lat.get("p99", 0.0) * 1e3,
        }
        results[name] = row
        emit(f"figserve/{name}/sequential", t_seq / n,
             f"host_syncs={n};qps={row['sequential_qps']:.0f}")
        emit(f"figserve/{name}/serve", t_srv / n,
             f"batches={row['batches']};host_syncs={row['host_syncs']};"
             f"occupancy={occ:.2f};speedup={row['speedup']:.2f}x;"
             f"p99={row['p99_ms']:.1f}ms")

    return write_bench(OUT_PATH, results)
