"""Fig. 5/6: ordered vs random query-to-ray mapping.

The paper shows ~5x slowdown for arbitrarily-ordered rays and corroborates
with L1/L2 hit rate + occupancy (Fig. 6). Here the timing contrast runs the
same window search on Morton-ordered vs shuffled query arrays; the
microarchitectural proxy is the adjacent-query cell-sharing statistic
(coherence_statistic), since CPU cache counters are not exposed.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SearchOpts, SearchParams, NeighborSearch,
                        coherence_statistic, schedule_queries)
from repro.data.pointclouds import kitti_like_cloud
from .common import emit, timeit


def run(n_points=40_000, n_queries_list=(10_000, 30_000), r=0.02, k=8):
    pts = kitti_like_cloud(n_points, seed=1)
    params = SearchParams(radius=r, k=k)
    ns = NeighborSearch(pts, params, SearchOpts(schedule=False,
                                                partition=False,
                                                bundle=False))
    for nq in n_queries_list:
        qs = kitti_like_cloud(nq, seed=2)
        rng = np.random.default_rng(0)
        shuffled = qs[rng.permutation(nq)]
        perm, _ = schedule_queries(ns.spec, jnp.asarray(shuffled))
        ordered = np.asarray(jnp.asarray(shuffled)[perm])

        t_ord = timeit(lambda q: ns.query(q), ordered, warmup=1, repeats=2)
        t_rnd = timeit(lambda q: ns.query(q), shuffled, warmup=1, repeats=2)
        c_ord = float(coherence_statistic(ns.spec, jnp.asarray(ordered)))
        c_rnd = float(coherence_statistic(ns.spec, jnp.asarray(shuffled)))
        emit(f"fig05/ordered_nq{nq}", t_ord / nq,
             f"coherence={c_ord:.3f}")
        emit(f"fig05/random_nq{nq}", t_rnd / nq,
             f"coherence={c_rnd:.3f};slowdown={t_rnd / t_ord:.2f}x")
