"""Fig. 15: structure-build time is linear in the number of points
(the paper regresses BVH build vs AABB count, R^2 = 0.996; we regress the
grid build the same way)."""
import jax.numpy as jnp
import numpy as np

from repro.core import build_cell_grid, choose_grid_spec
from repro.data.pointclouds import uniform_cloud
from .common import emit, timeit


def run():
    ns = [20_000, 40_000, 80_000, 160_000]
    ts = []
    for n in ns:
        pts = uniform_cloud(n, seed=1)
        spec = choose_grid_spec(pts, radius=0.02, cell_size=0.02)
        pj = jnp.asarray(pts)
        t = timeit(lambda: build_cell_grid(pj, spec))
        ts.append(t)
        emit(f"fig15/build_n{n}", t / n, "")
    # linear fit R^2
    x = np.asarray(ns, float)
    y = np.asarray(ts, float)
    coef = np.polyfit(x, y, 1)
    pred = np.polyval(coef, x)
    ss_res = np.sum((y - pred) ** 2)
    ss_tot = np.sum((y - y.mean()) ** 2)
    r2 = 1 - ss_res / max(ss_tot, 1e-30)
    emit("fig15/linear_fit", 0.0, f"R2={r2:.4f};k1={coef[0]:.3e}s_per_pt")
