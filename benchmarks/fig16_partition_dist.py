"""Fig. 16 + appendix C premise: query count per partition is inversely
correlated with its window (AABB) size — the structural fact the bundling
theorem rests on. Reported as the observed (window, count) table + the
rank correlation."""
import numpy as np

from repro.core import NeighborSearch, SearchOpts, SearchParams
from repro.data.pointclouds import clustered_cloud
from .common import emit


def run():
    # clustered data: modest sizes + explicit cell size keep the dense-cell
    # capacity (driven by the cluster cores) CPU-friendly
    pts = clustered_cloud(20_000, seed=1)
    # queries = the points themselves (the SPH/simulation regime the paper
    # evaluates): most queries sit in dense clusters -> small windows
    qs = pts[:: 4].copy()
    from repro.core import choose_grid_spec
    spec = choose_grid_spec(pts, radius=0.08, cell_size=0.0125)
    ns = NeighborSearch(pts, SearchParams(radius=0.08, k=16),
                        SearchOpts(bundle=False), spec=spec)
    ns.query(qs)
    import jax.numpy as jnp
    from repro.core.partition import compute_megacells
    plan_parts = []
    for b in ns.report.bundles:
        plan_parts.append((b.w_search, b.count))
    plan_parts.sort()
    ws = [w for w, _ in plan_parts]
    cs = [c for _, c in plan_parts]
    for w, c in plan_parts:
        emit(f"fig16/partition_w{w}", 0.0, f"queries={c}")
    if len(ws) > 2:
        rho = np.corrcoef(np.argsort(np.argsort(ws)),
                          np.argsort(np.argsort(cs)))[0, 1]
        emit("fig16/rank_correlation", 0.0, f"spearman={rho:.3f}")
