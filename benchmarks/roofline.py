"""Roofline table from the dry-run JSONs (deliverable g).

Reads experiments/dryrun/*.json and prints the per-(mesh x arch x shape)
three-term roofline + dominant bottleneck + MODEL_FLOPS ratio. Also used by
EXPERIMENTS.md generation (scripts write the section from this table).
"""
import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records():
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run():
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    for r in sorted(ok, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        t = r["roofline"]
        emit(
            f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}",
            max(t["compute_s"], t["memory_s"], t["collective_s"]),
            f"dominant={t['dominant']};compute={t['compute_s']:.3g}s;"
            f"memory={t['memory_s']:.3g}s;coll={t['collective_s']:.3g}s;"
            f"useful={t['useful_flops_ratio']:.2f}")
    emit("roofline/summary", 0.0,
         f"ok={len(ok)};skipped={len(skipped)};"
         f"errors={len(recs) - len(ok) - len(skipped)}")


if __name__ == "__main__":
    run()
