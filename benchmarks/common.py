"""Shared benchmark utilities. All timings block_until_ready; output rows
follow the ``name,us_per_call,derived`` CSV contract of run.py."""
from __future__ import annotations

import time

import jax
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def timeit(fn, *args, warmup: int = 1, repeats: int = 3) -> float:
    """Best-of wall time in seconds (post-compile)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts)


def emit(name: str, seconds_per_call: float, derived: str = ""):
    ROWS.append((name, seconds_per_call * 1e6, derived))
    print(f"{name},{seconds_per_call * 1e6:.1f},{derived}")


def flush_rows():
    out = list(ROWS)
    ROWS.clear()
    return out
