"""Shared benchmark utilities. All timings block_until_ready; output rows
follow the ``name,us_per_call,derived`` CSV contract of run.py."""
from __future__ import annotations

import json
import os
import time

import jax

ROWS: list[tuple[str, float, str]] = []


def provenance() -> dict:
    """Environment stamp for BENCH_*.json entries: the regression gate
    (scripts/check_bench.py) only compares ratios measured under the same
    backend/device-count, and the jax version makes the accumulated bench
    trajectory interpretable."""
    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": int(jax.device_count()),
    }


def write_bench(path: str, results: dict) -> dict:
    """Stamp each result row with :func:`provenance` and merge-accumulate
    into the JSON at ``path`` (the shared BENCH_*.json contract of the
    fig_* modules: existing cases from other smoke/full runs survive,
    same-name cases are replaced). Returns the stamped rows."""
    prov = provenance()
    stamped = {case: ({**row, "provenance": prov}
                      if isinstance(row, dict) else row)
               for case, row in results.items()}
    out = {}
    if os.path.exists(path):
        with open(path) as f:
            out = json.load(f)
    out.update(stamped)
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    return stamped


def timeit(fn, *args, warmup: int = 1, repeats: int = 3) -> float:
    """Best-of wall time in seconds (post-compile)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts)


def emit(name: str, seconds_per_call: float, derived: str = ""):
    ROWS.append((name, seconds_per_call * 1e6, derived))
    print(f"{name},{seconds_per_call * 1e6:.1f},{derived}")


def flush_rows():
    out = list(ROWS)
    ROWS.clear()
    return out
