"""Fig. 11: RTNN speedup over baselines, per input.

Baselines implemented in this repo (the paper's GPU libraries are not
portable here; these match their algorithmic classes):
  * brute        — exhaustive tiled distance scan (cuNSearch/FRNN class:
                   grid-free exhaustive work, hardware-friendly)
  * noopt        — the RT formulation with no optimizations (FastRNN class)
RTNN = scheduling + partitioning + bundling (full paper pipeline).
Speedups are per-dataset, mirroring the KITTI / scan / N-body regimes.
"""
import jax
import jax.numpy as jnp

from repro.core import NeighborSearch, SearchOpts, SearchParams
from repro.data.pointclouds import dataset_by_name
from repro.kernels.ref import brute_force_search
from .common import emit, timeit


def run(k=8):
    cases = [
        ("kitti-40k", "kitti", 40_000, 5_000, 0.02),
        ("scan-30k", "scan", 30_000, 5_000, 0.03),
        ("nbody-30k", "nbody", 30_000, 5_000, 0.03),
    ]
    for name, kind, n, nq, r in cases:
        pts = dataset_by_name(kind, n, seed=1)
        qs = dataset_by_name(kind, nq, seed=2)
        params = SearchParams(radius=r, k=k)

        t_brute = timeit(
            lambda: brute_force_search(jnp.asarray(pts), jnp.asarray(qs),
                                       r, k), warmup=1, repeats=2)
        ns_noopt = NeighborSearch(pts, params, SearchOpts(
            schedule=False, partition=False, bundle=False))
        t_noopt = timeit(lambda: ns_noopt.query(qs), warmup=1, repeats=2)
        ns_full = NeighborSearch(pts, params, SearchOpts())
        t_full = timeit(lambda: ns_full.query(qs), warmup=1, repeats=2)

        emit(f"fig11/{name}/brute", t_brute / nq, "")
        emit(f"fig11/{name}/noopt", t_noopt / nq,
             f"speedup_vs_brute={t_brute / t_noopt:.1f}x")
        emit(f"fig11/{name}/rtnn", t_full / nq,
             f"speedup_vs_brute={t_brute / t_full:.1f}x;"
             f"speedup_vs_noopt={t_noopt / t_full:.2f}x")
