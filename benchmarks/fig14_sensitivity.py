"""Fig. 14: range-search speedup sensitivity to r and K (Buddha-like
uniform scan data in a unit cube, as in the paper)."""
import jax.numpy as jnp

from repro.core import NeighborSearch, SearchOpts, SearchParams
from repro.data.pointclouds import uniform_cloud
from repro.kernels.ref import brute_force_search
from .common import emit, timeit


def run():
    pts = uniform_cloud(30_000, seed=1)
    qs = uniform_cloud(4_000, seed=2)

    for r in (0.01, 0.03, 0.1, 0.2):
        k = 16
        t_b = timeit(lambda: brute_force_search(
            jnp.asarray(pts), jnp.asarray(qs), r, k), warmup=1, repeats=2)
        ns = NeighborSearch(pts, SearchParams(radius=r, k=k, mode="range"),
                            SearchOpts())
        t_r = timeit(lambda: ns.query(qs), warmup=1, repeats=2)
        emit(f"fig14/r{r}", t_r / len(qs),
             f"speedup_vs_brute={t_b / t_r:.1f}x")

    for k in (1, 8, 32, 64):
        r = 0.05
        t_b = timeit(lambda: brute_force_search(
            jnp.asarray(pts), jnp.asarray(qs), r, k), warmup=1, repeats=2)
        ns = NeighborSearch(pts, SearchParams(radius=r, k=k, mode="range"),
                            SearchOpts())
        t_r = timeit(lambda: ns.query(qs), warmup=1, repeats=2)
        emit(f"fig14/K{k}", t_r / len(qs),
             f"speedup_vs_brute={t_b / t_r:.1f}x")
