"""Executor throughput: legacy per-bundle host loop vs device-resident
QueryExecutor vs the single-program traced Pallas pipeline on Fig. 11-style
workloads.

Measures steady-state end-to-end ``query()`` latency (plan/compile caches
warm — the SPH-stepping regime) plus the dispatch/sync counts that explain
it, asserts the paths return oracle-identical results, and writes the rows
to ``BENCH_executor.json`` at the repo root so the perf trajectory
accumulates across PRs. The ``pallas_traced`` column times
``jax.jit(api.query)`` with ``SearchOpts(use_pallas=True)`` — the
level-segmented fused-kernel schedule as ONE compiled program (DESIGN.md
section 3); on this CPU container the kernels run in interpret mode, so
that column measures orchestration structure, not kernel speed.

``REPRO_BENCH_SMOKE=1`` shrinks the workloads for CI (scripts/ci.sh).
"""
from __future__ import annotations

import os

import jax
import numpy as np

import repro.api as api
from repro.core import NeighborSearch, SearchOpts, SearchParams
from repro.data.pointclouds import dataset_by_name

from .common import emit, write_bench

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_executor.json")


def _paired_timeit(fn_a, fn_b, repeats: int = 5):
    """Interleaved best-of timing: alternating A/B runs so machine noise
    (shared CPU) hits both paths equally instead of biasing whichever ran
    in the quieter window."""
    import time

    import jax

    ts_a, ts_b = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ts_a.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        ts_b.append(time.perf_counter() - t0)
    return min(ts_a), min(ts_b)


def _assert_identical(a, b):
    assert np.array_equal(np.asarray(a.indices), np.asarray(b.indices))
    assert np.array_equal(np.asarray(a.counts), np.asarray(b.counts))
    da = np.where(np.isinf(np.asarray(a.distances2)), -1.0,
                  np.asarray(a.distances2))
    db = np.where(np.isinf(np.asarray(b.distances2)), -1.0,
                  np.asarray(b.distances2))
    assert np.array_equal(da, db)


def run(k=8):
    if SMOKE:
        # distinct name so the noisier 3-repeat smoke row never clobbers
        # the committed full-run row under the merge-accumulate write
        cases = [("kitti-stream-512-smoke", "kitti", 8_000, 512, 0.04, 128)]
    else:
        # batch cases: Fig. 11 regimes (kernel-bound; the executor must not
        # regress). stream cases: small repeated batches, the serving/SPH
        # steady state where host orchestration is a visible fraction and
        # the one-sync compiled schedule pays off.
        cases = [
            ("kitti-40k", "kitti", 40_000, 5_000, 0.02, 256),
            ("scan-30k", "scan", 30_000, 5_000, 0.03, 256),
            ("nbody-30k", "nbody", 30_000, 5_000, 0.03, 256),
            ("kitti-stream-512", "kitti", 8_000, 512, 0.04, 128),
            ("nbody-stream-512", "nbody", 8_000, 512, 0.04, 128),
        ]
    results = {}
    for name, kind, n, nq, r, tile in cases:
        pts = dataset_by_name(kind, n, seed=1)
        qs = dataset_by_name(kind, nq, seed=2)
        params = SearchParams(radius=r, k=k)

        ns_old = NeighborSearch(pts, params,
                                SearchOpts(executor=False, query_tile=tile))
        res_old = ns_old.query(qs)                       # warm jit caches
        ns_new = NeighborSearch(pts, params, SearchOpts(query_tile=tile))
        ns_new.executor.warmup(qs)
        res_new = ns_new.query(qs)
        _assert_identical(res_old, res_new)
        t_old, t_new = _paired_timeit(lambda: ns_old.query(qs),
                                      lambda: ns_new.query(qs),
                                      repeats=3 if SMOKE else 7)
        st = ns_new.executor.stats()

        # single-program traced Pallas pipeline: jit(api.query), the whole
        # schedule->anchor->gather->knn as one compiled program. Interpret
        # mode emulates the kernels in Python, so on CPU containers the
        # column is only affordable on the stream-sized cases; compiled
        # TPU runs (PALLAS_INTERPRET=0) measure every case.
        from repro.kernels.ops import INTERPRET
        t_tr = None
        if not INTERPRET or nq <= 1024:
            index_p = api.build_index(pts, params,
                                      SearchOpts(use_pallas=True,
                                                 query_tile=tile))
            traced = jax.jit(api.query)
            qs_dev = np.asarray(qs, np.float32)
            res_tr = traced(index_p, qs_dev)             # warm compile
            # distances/counts are bitwise across the fused and jnp paths
            # (indices only up to ties) — hold the timed column to that
            assert np.array_equal(np.asarray(res_tr.counts),
                                  np.asarray(res_new.counts))
            d_tr = np.where(np.isinf(np.asarray(res_tr.distances2)), -1.0,
                            np.asarray(res_tr.distances2))
            d_ex = np.where(np.isinf(np.asarray(res_new.distances2)), -1.0,
                            np.asarray(res_new.distances2))
            assert np.array_equal(d_tr, d_ex)
            _, t_tr = _paired_timeit(lambda: ns_new.query(qs),
                                     lambda: traced(index_p, qs_dev),
                                     repeats=3 if SMOKE else 7)

        row = {
            "old_us": t_old * 1e6,
            "new_us": t_new * 1e6,
            "pallas_traced_us": None if t_tr is None else t_tr * 1e6,
            "speedup": t_old / t_new,
            "bundles": len(ns_new.report.bundles),
            "launches_old": ns_old.report.launches,
            "launches_new": ns_new.report.launches,
            "host_syncs_old": ns_old.report.host_syncs,
            "host_syncs_new": ns_new.report.host_syncs,
            "steady_state_compilations": st["last"]["compilations"],
            "plan_cache_hit": st["last"]["plan_cache_hit"],
        }
        results[name] = row
        emit(f"figtp/{name}/host_loop", t_old / nq,
             f"launches={row['launches_old']};"
             f"host_syncs={row['host_syncs_old']}")
        emit(f"figtp/{name}/executor", t_new / nq,
             f"launches={row['launches_new']};host_syncs=1;"
             f"speedup={row['speedup']:.2f}x")
        if t_tr is not None:
            emit(f"figtp/{name}/pallas-traced", t_tr / nq,
                 "one compiled program;interpret-mode kernels")

    return write_bench(OUT_PATH, results)
