"""Sharded-scene benchmark (DESIGN.md section 6): slab-resident
``ShardedSession`` vs the single-device ``SimulationSession`` on the
identical drifting trajectory.

Two regimes:

* ``shard-1slab`` — a 1-slab mesh on the real device: measures the pure
  overhead of the sharded machinery (traced routing, halo/migration
  plumbing with no neighbors, per-slab plan state) against the plain
  session. This is the parity row: speedup ~1 means scale-out costs
  nothing when you don't scale.
* ``shard-{S}slab-hostdev`` — a subprocess under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``: S slabs on 8
  forced host devices vs the single-device session in the same process.
  On one physical CPU the forced devices time-slice, so this measures the
  *scaling structure* (per-slab work shrinks with S, communication is
  O(surface)) rather than real speedup — the ratio is the tracked
  statistic, machine speed cancels.

Every timed frame is asserted count-exact between the two paths. Rows
merge-accumulate into ``BENCH_shard.json`` (committed baseline is the CI
regression gate — scripts/check_bench.py). ``REPRO_BENCH_SMOKE=1``
shrinks the workload for CI.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_shard.json")

_WORKER = r"""
import json, sys, time
import numpy as np
from repro.core import SearchParams, ShardedSession, SimulationSession

n, steps, n_slabs, radius, k = json.loads(sys.argv[1])
rng = np.random.default_rng(17)
pos = rng.random((n, 3)).astype(np.float32)
vel = rng.normal(0, 0.03 * radius / 4.0, (n, 3)).astype(np.float32)
frames = [pos]
for _ in range(steps - 1):
    vel = 0.9 * vel + rng.normal(0, 0.3 * 0.03 * radius / 4.0,
                                 (n, 3)).astype(np.float32)
    pos = np.clip(pos + vel, 0.0, 1.0).astype(np.float32)
    frames.append(pos)
params = SearchParams(radius=radius, k=k, knn_window="exact")

sharded = ShardedSession(frames[0], params, n_slabs=n_slabs)
single = SimulationSession(frames[0], params)
rs = sharded.step(frames[0])            # warm compile + plan (both paths)
rr = single.step(frames[0])
ts_sh, ts_si = [], []
for f in frames[1:]:
    t0 = time.perf_counter(); rs = sharded.step(f)
    ts_sh.append(time.perf_counter() - t0)
    t0 = time.perf_counter(); rr = single.step(f)
    ts_si.append(time.perf_counter() - t0)
    assert np.array_equal(np.asarray(rs.counts), np.asarray(rr.counts))
st = sharded.stats()
print("RESULT", json.dumps({
    "single_us_per_step": float(np.median(ts_si)) * 1e6,
    "sharded_us_per_step": float(np.median(ts_sh)) * 1e6,
    "speedup": float(np.median(ts_si)) / float(np.median(ts_sh)),
    "n_slabs": n_slabs, "points": n, "steps": steps,
    "fast_steps": st["fast_steps"], "replans": st["replans"],
    "migrated": st["migrated"], "host_routings": st["host_routings"],
}))
"""


def _run_case(n, steps, n_slabs, radius, k, devices):
    env = {**os.environ,
           "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..",
                                      "src"),
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}"}
    args = json.dumps([n, steps, n_slabs, radius, k])
    r = subprocess.run([sys.executable, "-c", _WORKER, args], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"fig_shard worker failed:\n{r.stderr}")
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def _parity_case(n, steps, radius, k):
    """In-process 1-slab parity (single real device)."""
    from repro.core import (SearchParams, ShardedSession,
                            SimulationSession)
    rng = np.random.default_rng(17)
    pos = rng.random((n, 3)).astype(np.float32)
    sigma = 0.03 * radius / 4.0
    vel = rng.normal(0, sigma, (n, 3)).astype(np.float32)
    frames = [pos]
    for _ in range(steps - 1):
        vel = 0.9 * vel + rng.normal(0, 0.3 * sigma,
                                     (n, 3)).astype(np.float32)
        pos = np.clip(pos + vel, 0.0, 1.0).astype(np.float32)
        frames.append(pos)
    params = SearchParams(radius=radius, k=k, knn_window="exact")
    sharded = ShardedSession(frames[0], params, n_slabs=1)
    single = SimulationSession(frames[0], params)
    sharded.step(frames[0])
    single.step(frames[0])
    ts_sh, ts_si = [], []
    for f in frames[1:]:
        t0 = time.perf_counter()
        rs = sharded.step(f)
        ts_sh.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        rr = single.step(f)
        ts_si.append(time.perf_counter() - t0)
        assert np.array_equal(np.asarray(rs.counts),
                              np.asarray(rr.counts))
    st = sharded.stats()
    return {
        "single_us_per_step": float(np.median(ts_si)) * 1e6,
        "sharded_us_per_step": float(np.median(ts_sh)) * 1e6,
        "speedup": float(np.median(ts_si)) / float(np.median(ts_sh)),
        "n_slabs": 1, "points": n, "steps": steps,
        "fast_steps": st["fast_steps"], "replans": st["replans"],
        "host_routings": st["host_routings"],
    }


def run():
    from .common import emit, write_bench
    if SMOKE:
        n, steps, slabs = 2_000, 9, 4
    else:
        n, steps, slabs = 8_000, 12, 4
    radius, k = 0.05, 8
    results = {}

    row = _parity_case(n, steps, radius, k)
    name = "shard-1slab"
    results[name] = row
    emit(f"figshard/{name}/single", row["single_us_per_step"] / 1e6 / n,
         "plain session")
    emit(f"figshard/{name}/sharded", row["sharded_us_per_step"] / 1e6 / n,
         f"parity={row['speedup']:.2f}x;routing={row['host_routings']}")

    row = _run_case(n, steps, slabs, radius, k, devices=8)
    name = f"shard-{slabs}slab-hostdev"
    results[name] = row
    emit(f"figshard/{name}/single", row["single_us_per_step"] / 1e6 / n,
         "single device")
    emit(f"figshard/{name}/sharded", row["sharded_us_per_step"] / 1e6 / n,
         f"speedup={row['speedup']:.2f}x;migrated={row['migrated']};"
         f"routing={row['host_routings']}")

    return write_bench(OUT_PATH, results)
