"""Fig. 7 + Fig. 8: search time and Step-2 candidate count vs AABB width.

The TPU analogue of AABB width is the candidate-window width in cells
(DESIGN.md section 2). Fig. 8's IS-call count is exactly our per-query
candidate count (deterministic, hardware-independent); Fig. 7's time curve
is the window search timed per window radius.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_cell_grid, choose_grid_spec
from repro.core.grid import box_count, clamp_box
from repro.core.search import window_search
from repro.data.pointclouds import uniform_cloud
from .common import emit, timeit


def run(n_points=60_000, n_queries=8_192, k=8, cell=0.02):
    pts = uniform_cloud(n_points, seed=1)
    qs = uniform_cloud(n_queries, seed=2)
    spec = choose_grid_spec(pts, radius=cell, cell_size=cell)
    grid = build_cell_grid(jnp.asarray(pts), spec)
    qj = jnp.asarray(qs)
    ccoord = spec.cell_of(qj)

    for w in (1, 2, 3, 4, 6):
        width = (2 * w + 1) * cell
        radius = width / 2  # search radius implied by this window
        t = timeit(
            lambda: window_search(grid, jnp.asarray(pts), qj, spec, w,
                                  radius, k, False, 256))
        lo, hi = clamp_box(spec, ccoord, w)
        cand = int(jnp.sum(box_count(grid.sat, lo, hi)))
        emit(f"fig07/search_w{w}", t / n_queries,
             f"aabb_width={width:.3f}")
        emit(f"fig08/is_calls_w{w}", 0.0,
             f"candidates_per_query={cand / n_queries:.1f}")
