"""Benchmark driver: one module per paper figure/table (DESIGN.md section 5
index) + the dry-run roofline table. Prints ``name,us_per_call,derived``
CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig11      # one figure
"""
import sys
import time


def main() -> None:
    from . import (fig05_coherence, fig07_aabb_width, fig11_speedup,
                   fig12_breakdown, fig13_ablation, fig14_sensitivity,
                   fig15_build_time, fig16_partition_dist, fig_batch,
                   fig_dynamic, fig_throughput, roofline)
    modules = {
        "fig05": fig05_coherence, "fig07": fig07_aabb_width,
        "fig11": fig11_speedup, "fig12": fig12_breakdown,
        "fig13": fig13_ablation, "fig14": fig14_sensitivity,
        "fig15": fig15_build_time, "fig16": fig16_partition_dist,
        "figbatch": fig_batch, "figdyn": fig_dynamic,
        "figtp": fig_throughput, "roofline": roofline,
    }
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for key, mod in modules.items():
        if only and not key.startswith(only):
            continue
        t0 = time.time()
        mod.run()
        print(f"# {key} done in {time.time() - t0:.1f}s")


if __name__ == '__main__':
    main()
