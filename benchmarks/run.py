"""Benchmark driver: one module per paper figure/table (DESIGN.md section 5
index) + the dry-run roofline table. Prints ``name,us_per_call,derived``
CSV rows, then a per-figure summary table (name, old_us, new_us, speedup)
so the BENCH_* deltas are reviewable without opening the JSON.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig11      # one figure
"""
import sys
import time

# per-module mapping of row keys onto the summary's (old, new) columns —
# modules whose run() returns {case: {key: value}} rows with an A/B pair
_SUMMARY_COLS = {
    "figtp": ("old_us", "new_us"),
    "figbatch": ("sequential_us_per_frame", "vmapped_us_per_frame"),
    "figdyn": ("rebuild_us_per_step", "session_us_per_step"),
    "figshard": ("single_us_per_step", "sharded_us_per_step"),
    "figserve": ("sequential_us_per_req", "serve_us_per_req"),
}


def _summarize(key: str, results) -> list[tuple]:
    """Rows (name, old_us, new_us, speedup) for the summary table."""
    if not isinstance(results, dict):
        return []
    old_key, new_key = _SUMMARY_COLS.get(key, (None, None))
    rows = []
    for case, row in sorted(results.items()):
        if not isinstance(row, dict):
            continue
        if old_key in row and new_key in row:
            old_us, new_us = float(row[old_key]), float(row[new_key])
            rows.append((f"{key}/{case}", old_us, new_us,
                         old_us / new_us if new_us else float("nan")))
            if row.get("pallas_traced_us"):
                rows.append((f"{key}/{case}/pallas-traced", old_us,
                             float(row["pallas_traced_us"]),
                             old_us / float(row["pallas_traced_us"])))
    return rows


def _print_summary(rows: list[tuple]) -> None:
    if not rows:
        return
    name_w = max(len(r[0]) for r in rows) + 2
    print("\n# ---- summary (old vs new, best-of timings) ----")
    print(f"# {'name':<{name_w}}{'old_us':>12}{'new_us':>12}{'speedup':>9}")
    for name, old_us, new_us, speedup in rows:
        print(f"# {name:<{name_w}}{old_us:>12.1f}{new_us:>12.1f}"
              f"{speedup:>8.2f}x")


def main() -> None:
    from . import (fig05_coherence, fig07_aabb_width, fig11_speedup,
                   fig12_breakdown, fig13_ablation, fig14_sensitivity,
                   fig15_build_time, fig16_partition_dist, fig_batch,
                   fig_dynamic, fig_serve, fig_shard, fig_throughput,
                   roofline)
    modules = {
        "fig05": fig05_coherence, "fig07": fig07_aabb_width,
        "fig11": fig11_speedup, "fig12": fig12_breakdown,
        "fig13": fig13_ablation, "fig14": fig14_sensitivity,
        "fig15": fig15_build_time, "fig16": fig16_partition_dist,
        "figbatch": fig_batch, "figdyn": fig_dynamic,
        "figserve": fig_serve, "figshard": fig_shard,
        "figtp": fig_throughput, "roofline": roofline,
    }
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    summary = []
    for key, mod in modules.items():
        if only and not key.startswith(only):
            continue
        t0 = time.time()
        results = mod.run()
        print(f"# {key} done in {time.time() - t0:.1f}s")
        summary.extend(_summarize(key, results))
    _print_summary(summary)
    # the unified telemetry registry (executor / session / serve counters
    # and latency percentiles collected while the figures ran) follows the
    # ratio table — metrics record regardless of REPRO_TRACE, so the table
    # prints unconditionally
    from repro import obs
    from repro.obs import slo
    print()
    print(obs.summary())
    # ... and the per-tenant SLO table whenever serve figures ran (the
    # board has tenants exactly when a NeighborService resolved traffic)
    if slo.BOARD.tenants():
        print()
        print(slo.summary())


if __name__ == '__main__':
    main()
