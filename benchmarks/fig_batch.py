"""Multi-scene batching: one vmapped functional query over B stacked
same-spec scenes vs B sequential ``SimulationSession``s (DESIGN.md
section 8 — the ROADMAP's "multi-session batching" item).

Both paths advance B independent drifting scenes through the IDENTICAL
frame trajectories and self-query every frame. The sequential path is B
persistent sessions stepped back to back (each already device-resident
with plan replay); the batched path is ONE jitted program —
``vmap(update_index + with_anchor + query)`` over the stacked scene
leaves — so B scenes cost one dispatch and XLA batches the whole
pipeline. Correctness is asserted scene-by-scene against the session
results every timed frame.

Writes per-case rows to ``BENCH_batch.json`` at the repo root.
``REPRO_BENCH_SMOKE=1`` shrinks the workload for CI (scripts/ci.sh).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as api
from repro.core import (SearchOpts, SearchParams, SimulationSession,
                        choose_grid_spec)

from .common import emit, write_bench

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_batch.json")


def _trajectories(b: int, n: int, steps: int, sigma: float,
                  seed: int) -> list[list[np.ndarray]]:
    """B independent coherently-drifting clouds (same regime as figdyn)."""
    out = []
    for s in range(b):
        rng = np.random.default_rng(seed + s)
        pos = rng.random((n, 3)).astype(np.float32)
        vel = rng.normal(0, sigma, (n, 3)).astype(np.float32)
        frames = [pos]
        for _ in range(steps - 1):
            vel = 0.9 * vel + rng.normal(0, 0.3 * sigma,
                                         (n, 3)).astype(np.float32)
            pos = np.clip(pos + vel, 0.0, 1.0).astype(np.float32)
            frames.append(pos)
        out.append(frames)
    return out


def _assert_close(a, b):
    da = np.where(np.isinf(np.asarray(a.distances2)), -1.0,
                  np.asarray(a.distances2))
    db = np.where(np.isinf(np.asarray(b.distances2)), -1.0,
                  np.asarray(b.distances2))
    np.testing.assert_allclose(da, db, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(a.counts),
                                  np.asarray(b.counts))


def run(k=16):
    if SMOKE:
        sizes, n, steps, radius = [2], 1_500, 5, 0.05
    else:
        sizes, n, steps, radius = [2, 4, 8], 6_000, 10, 0.04
    results = {}
    for b in sizes:
        name = f"B{b}-{n // 1000}k"
        trajs = _trajectories(b, n, steps, sigma=0.03 * radius / 4.0,
                              seed=11)
        params = SearchParams(radius=radius, k=k, mode="range")

        # one shared spec so the B scenes share one trace/compile; sized
        # over the union so no scene can overflow it
        spec = choose_grid_spec(
            np.concatenate([t[0] for t in trajs]), radius,
            capacity_slack=1.5, domain_margin=radius)

        # --- sequential baseline: B persistent sessions -------------------
        sessions = [SimulationSession(t[0], params, SearchOpts(), spec=spec)
                    for t in trajs]
        for sess, t in zip(sessions, trajs):
            sess.step(t[0])                       # warm compile + plan

        # --- batched path: ONE vmapped update+query program ---------------
        def one_scene(idx, pts):
            idx2, _stats = api.update_index(idx, pts)
            idx2 = idx2.with_anchor(pts)
            return idx2, api.query(idx2, pts)

        batch_step = jax.jit(jax.vmap(one_scene))
        idxs = [api.build_index(t[0], params, SearchOpts(), spec=spec)
                for t in trajs]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *idxs)
        stacked, _ = batch_step(stacked, jnp.stack(
            [jnp.asarray(t[0]) for t in trajs]))     # warm compile

        ts_seq, ts_bat = [], []
        for f in range(1, steps):
            frames = [t[f] for t in trajs]
            t0 = time.perf_counter()
            res_seq = [sess.step(fr) for sess, fr in zip(sessions, frames)]
            jax.block_until_ready([r.indices for r in res_seq])
            ts_seq.append(time.perf_counter() - t0)

            fstack = jnp.stack([jnp.asarray(fr) for fr in frames])
            t0 = time.perf_counter()
            stacked, res_bat = batch_step(stacked, fstack)
            jax.block_until_ready(res_bat.indices)
            ts_bat.append(time.perf_counter() - t0)

            for s in range(b):
                _assert_close(
                    type(res_seq[s])(indices=res_bat.indices[s],
                                     distances2=res_bat.distances2[s],
                                     counts=res_bat.counts[s]),
                    res_seq[s])

        t_s = float(np.median(ts_seq))
        t_b = float(np.median(ts_bat))
        row = {
            "scenes": b,
            "points_per_scene": n,
            "sequential_us_per_frame": t_s * 1e6,
            "vmapped_us_per_frame": t_b * 1e6,
            "speedup": t_s / t_b,
        }
        results[name] = row
        emit(f"figbatch/{name}/sequential", t_s / (b * n),
             "B sessions back to back")
        emit(f"figbatch/{name}/vmapped", t_b / (b * n),
             f"speedup={row['speedup']:.2f}x;one program")

    return write_bench(OUT_PATH, results)
