"""Fig. 12: execution-time breakdown (Data/Opt/BVH/FS/Search categories).

Data-transfer (Data) is host->device copy of points+queries; BVH is the
grid build; Opt is scheduling+partitioning+bundling planning; FS (the
paper's first-hit ray pass) is closed-form on the grid, so it is part of
Opt here and reported as 0 (documented adaptation, DESIGN.md section 2).
"""
import time

import jax
import jax.numpy as jnp

from repro.core import NeighborSearch, SearchOpts, SearchParams
from repro.core.grid import build_cell_grid
from repro.data.pointclouds import dataset_by_name
from .common import emit


def run(k=8):
    for name, kind, n, nq, r in [("kitti-40k", "kitti", 40_000, 6_000,
                                  0.02),
                                 ("nbody-30k", "nbody", 30_000, 6_000,
                                  0.03)]:
        pts = dataset_by_name(kind, n, seed=1)
        qs = dataset_by_name(kind, nq, seed=2)

        t0 = time.perf_counter()
        pj = jax.block_until_ready(jnp.asarray(pts))
        qj = jax.block_until_ready(jnp.asarray(qs))
        t_data = time.perf_counter() - t0

        t0 = time.perf_counter()
        ns = NeighborSearch(pts, SearchParams(radius=r, k=k), SearchOpts())
        jax.block_until_ready(ns.grid.dense)
        t_build = time.perf_counter() - t0

        ns.query(qs)                      # warm/compile
        ns.query(qs)
        rep = ns.report
        total = t_data + t_build + rep.t_opt + rep.t_search
        for cat, t in [("Data", t_data), ("BVH", t_build),
                       ("Opt", rep.t_opt), ("FS", 0.0),
                       ("Search", rep.t_search)]:
            emit(f"fig12/{name}/{cat}", t / nq,
                 f"frac={t / total * 100:.1f}%")
