"""Dynamic-scene stepping: persistent ``SimulationSession`` vs the legacy
rebuild-per-frame path on a steady-state SPH-like workload (DESIGN.md
section 7).

Both paths see the IDENTICAL precomputed position trajectory (coherent
drift + jitter, bounded to the unit box, displacement per step a fraction
of a cell — the temporal-coherence regime of frame-stepped solvers). The
rebuild path is exactly what ``examples/sph_fluid.py --rebuild`` does: a
fresh ``NeighborSearch`` every frame, so it pays host spec planning, a full
grid build, schedule/partition/bundle replanning, and — because the
re-chosen spec differs frame to frame — recompilation. The session path
pays an incremental device-resident update plus a cached-plan replay.

Writes per-case rows to ``BENCH_dynamic.json`` at the repo root so the
perf trajectory accumulates across PRs. ``REPRO_BENCH_SMOKE=1`` shrinks
the workload for CI (scripts/ci.sh).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import (NeighborSearch, SearchOpts, SearchParams,
                        SimulationSession)

from .common import emit, write_bench

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_dynamic.json")


def _trajectory(n: int, steps: int, seed: int,
                sigma: float) -> list[np.ndarray]:
    """Coherently drifting cloud: per-point velocity random walk, clipped
    to the unit box (reflecting the SPH wall behavior)."""
    rng = np.random.default_rng(seed)
    pos = rng.random((n, 3)).astype(np.float32)
    vel = rng.normal(0, sigma, (n, 3)).astype(np.float32)
    frames = [pos]
    for _ in range(steps - 1):
        vel = 0.9 * vel + rng.normal(0, 0.3 * sigma,
                                     (n, 3)).astype(np.float32)
        pos = np.clip(pos + vel, 0.0, 1.0).astype(np.float32)
        frames.append(pos)
    return frames


def _assert_close(a, b):
    da = np.where(np.isinf(np.asarray(a.distances2)), -1.0,
                  np.asarray(a.distances2))
    db = np.where(np.isinf(np.asarray(b.distances2)), -1.0,
                  np.asarray(b.distances2))
    np.testing.assert_allclose(da, db, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(a.counts),
                                  np.asarray(b.counts))


def run(k=16):
    if SMOKE:
        cases = [("sph-2k", 2_000, 6, 0.05)]
    else:
        cases = [
            ("sph-8k", 8_000, 15, 0.04),
            ("sph-20k", 20_000, 12, 0.03),
        ]
    results = {}
    for name, n, steps, radius in cases:
        # velocity scale ~0.03 cells/step (default cell = radius/4): the
        # worst-moving point then drifts ~0.1 cell per step, so the session
        # replays its plan for a handful of frames between replans — the
        # steady-state solver regime (SPH CFL-limited steps move far less
        # than a cell)
        frames = _trajectory(n, steps, seed=7,
                             sigma=0.03 * radius / 4.0)
        params = SearchParams(radius=radius, k=k, mode="range")

        def rebuild_once(f):
            ns = NeighborSearch(f, params, SearchOpts())
            return ns.query(f)

        sess = SimulationSession(frames[0], params, SearchOpts())
        res_s = sess.step(frames[0])                 # warm compile + plan
        res_r = rebuild_once(frames[0])              # warm shared jit caches
        # interleaved stepping: both paths advance through the SAME frames
        # back to back, so machine noise hits them equally (cf. figtp's
        # paired timing)
        ts_session, ts_rebuild = [], []
        for f in frames[1:]:
            t0 = time.perf_counter()
            res_s = sess.step(f)
            ts_session.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            res_r = rebuild_once(f)
            ts_rebuild.append(time.perf_counter() - t0)
        st = sess.stats()

        _assert_close(res_s, res_r)                  # final frame, same math

        t_s = float(np.median(ts_session))
        t_r = float(np.median(ts_rebuild))
        row = {
            "session_us_per_step": t_s * 1e6,
            "rebuild_us_per_step": t_r * 1e6,
            "speedup": t_r / t_s,
            "steps": steps,
            "fast_steps": st.get("fast_steps", 0),
            "replans": st.get("replans", 0),
            "respecs": st.get("respecs", 0),
        }
        results[name] = row
        emit(f"figdyn/{name}/rebuild", t_r / n, "per-frame teardown")
        emit(f"figdyn/{name}/session", t_s / n,
             f"speedup={row['speedup']:.2f}x;"
             f"fast={row['fast_steps']}/{steps};"
             f"replans={row['replans']}")

    return write_bench(OUT_PATH, results)
