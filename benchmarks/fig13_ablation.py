"""Fig. 13: optimization ablation — NoOpt / +Sched / +Partition / +Bundle /
Oracle, on a KITTI-like and an N-body-like input (the paper's two
representative regimes; partitioning over-fragments on N-body)."""
import numpy as np

from repro.core import NeighborSearch, SearchOpts, SearchParams
from repro.data.pointclouds import dataset_by_name
from .common import emit, timeit


VARIANTS = [
    ("noopt", SearchOpts(schedule=False, partition=False, bundle=False)),
    ("sched", SearchOpts(schedule=True, partition=False, bundle=False)),
    ("sched+part", SearchOpts(schedule=True, partition=True, bundle=False)),
    ("sched+part+bundle", SearchOpts(schedule=True, partition=True,
                                     bundle=True)),
]


def run(k=8):
    for name, kind, n, nq, r in [("kitti-40k", "kitti", 40_000, 6_000,
                                  0.03),
                                 ("nbody-30k", "nbody", 30_000, 6_000,
                                  0.03)]:
        pts = dataset_by_name(kind, n, seed=1)
        qs = dataset_by_name(kind, nq, seed=2)
        params = SearchParams(radius=r, k=k)
        times = {}
        for vname, opts in VARIANTS:
            ns = NeighborSearch(pts, params, opts)
            times[vname] = timeit(lambda: ns.query(qs), warmup=1, repeats=2)
        base = times["noopt"]
        # Oracle: best of (all variants) — a-priori knowledge of whether to
        # partition, matching the paper's definition
        oracle = min(times.values())
        for vname, t in times.items():
            emit(f"fig13/{name}/{vname}", t / nq,
                 f"speedup_vs_noopt={base / t:.2f}x")
        emit(f"fig13/{name}/oracle", oracle / nq,
             f"speedup_vs_noopt={base / oracle:.2f}x")
