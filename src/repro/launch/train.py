"""End-to-end training driver.

Runs real training (materialized params) on whatever devices exist: the
CPU container trains reduced/100M configs; the same driver drives the
production mesh on a real fleet. Fault tolerance comes from ResilientLoop
(checkpoint/restart + straggler monitor).

  PYTHONPATH=src python -m repro.launch.train --arch lm-100m --steps 50 \
      --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch rwkv6-7b --smoke ...
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config of --arch")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import smoke_config
    from repro.data.pipeline import make_batch, synthetic_stream
    from repro.models.config import get_config
    from repro.models.model import count_params, init_params
    from repro.train.checkpoint import CheckpointManager
    from repro.train.fault_tolerance import ResilientLoop
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.train_step import make_train_step

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    print(f"arch={cfg.name} params={count_params(cfg)/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(100, args.steps // 5
                                                     or 1))
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    def stream_fn(start):
        def add_micro(b):
            return jax.tree.map(
                lambda a: a.reshape((args.n_micro,
                                     a.shape[0] // args.n_micro)
                                    + a.shape[1:]), b)
        it = synthetic_stream(cfg, args.batch, args.seq, start_step=start,
                              seed=args.seed)
        return (add_micro(b) for b in it)

    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        loop = ResilientLoop(ckpt, save_every=args.save_every)
        start = ckpt.latest_step() or 0
        if start:
            params, opt_state, _ = ckpt.restore(params, opt_state)
            print(f"resumed from step {start}")
        params, opt_state, log = loop.run(step_fn, params, opt_state,
                                          stream_fn, args.steps, start)
        for i, m in enumerate(log):
            if i % args.log_every == 0:
                print(f"step {start + i:5d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f}")
    else:
        stream = stream_fn(0)
        t0 = time.perf_counter()
        for s in range(args.steps):
            batch = next(stream)
            params, opt_state, m = step_fn(params, opt_state, batch)
            if s % args.log_every == 0:
                dt = time.perf_counter() - t0
                tok = args.batch * args.seq
                print(f"step {s:5d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f} "
                      f"({tok / max(dt, 1e-9):.0f} tok/s)")
                t0 = time.perf_counter()
    print("done")


if __name__ == "__main__":
    main()
