"""Production mesh construction.

A function (not a module constant) so importing this module never touches
jax device state. Single pod: 16x16 = 256 chips (data x model).
Multi-pod: 2x16x16 = 512 chips; the leading "pod" axis is pure data
parallelism across pods (ICI within a pod, DCI across).
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    from jax.sharding import Mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{max(n, 512)} (dryrun.py sets this automatically)")
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev_array, axes)


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across JAX versions: newer JAX wants explicit
    ``axis_types`` on meshes fed to shard_map, older JAX has no such kwarg
    (and no ``jax.sharding.AxisType``). Feature-detect, don't version-sniff.
    """
    import jax

    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests."""
    import jax
    from jax.sharding import Mesh

    n = int(np.prod(shape))
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev_array, axes)
