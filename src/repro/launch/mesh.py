"""Production mesh construction.

A function (not a module constant) so importing this module never touches
jax device state. Single pod: 16x16 = 256 chips (data x model).
Multi-pod: 2x16x16 = 512 chips; the leading "pod" axis is pure data
parallelism across pods (ICI within a pod, DCI across).
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    from jax.sharding import Mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{max(n, 512)} (dryrun.py sets this automatically)")
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev_array, axes)


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across JAX versions: newer JAX wants explicit
    ``axis_types`` on meshes fed to shard_map, older JAX has no such kwarg
    (and no ``jax.sharding.AxisType``). Feature-detect, don't version-sniff.
    """
    import jax

    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_slab_mesh(n_slabs: int | None = None, axis: str = "data"):
    """1-D slab mesh for the sharded-scene subsystem (``core/shards.py``).

    Defaults to one slab per local device; asks for the
    ``xla_force_host_platform_device_count`` escape hatch when more slabs
    than devices are requested (CPU CI runs the mesh paths under 8 forced
    host devices — see scripts/ci.sh).
    """
    import jax

    devs = jax.devices()
    n = int(n_slabs) if n_slabs else len(devs)
    if n > len(devs):
        raise RuntimeError(
            f"need {n} devices for a {n}-slab mesh, have {len(devs)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n}")
    if n == len(devs):
        return make_mesh_compat((n,), (axis,))
    from jax.sharding import Mesh

    dev_array = np.asarray(devs[:n]).reshape(n)
    try:
        from jax.sharding import AxisType
        return Mesh(dev_array, (axis,), axis_types=(AxisType.Auto,))
    except (ImportError, TypeError):
        return Mesh(dev_array, (axis,))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests."""
    import jax
    from jax.sharding import Mesh

    n = int(np.prod(shape))
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev_array, axes)
