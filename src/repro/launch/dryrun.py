import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds ShapeDtypeStruct stand-ins for params,
optimizer state, batch and caches (no allocation), jits the step with the
production in/out shardings, runs ``.lower().compile()``, prints
``memory_analysis()`` / ``cost_analysis()`` and records the roofline terms
(EXPERIMENTS.md sections Dry-run and Roofline read the JSONs written here).

Usage:
  python -m repro.launch.dryrun --arch rwkv6-7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALL_ARCHS, SHAPES, applicable
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import batch_specs
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.models.config import ArchConfig, get_config
from repro.models.model import (apply_layer, count_params, init_decode_cache,
                                init_params, layer_groups)
from repro.sharding.rules import (batch_axes, cache_pspecs, make_shard_fn,
                                  named_sharding_tree, opt_pspecs,
                                  param_pspecs)
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")
PARAM_DTYPE = jnp.bfloat16


def microbatching(cfg: ArchConfig, shape: ShapeSpec, mesh) -> tuple[int, int]:
    """(n_micro, per-micro global batch) for train cells: B_local scales
    inversely with parameter count to bound activation memory."""
    n_b = int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))
    params_b = count_params(cfg) / 1e9
    b_local = 1 if params_b > 50 else (4 if params_b > 5 else 16)
    b_micro = min(shape.global_batch, n_b * b_local)
    while shape.global_batch % b_micro:
        b_micro -= n_b
    n_micro = shape.global_batch // b_micro
    return n_micro, b_micro


def _batch_shardings(specs: dict, mesh, *, micro_axis: bool) -> dict:
    baxes = batch_axes(mesh)
    n_b = int(np.prod([mesh.shape[a] for a in baxes]))
    out = {}
    for k, v in specs.items():
        lead = 1 if micro_axis else 0
        bdim = v.shape[lead]
        spec = [None] * v.ndim
        if baxes and bdim % n_b == 0:
            spec[lead] = baxes
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def _with_micro_axis(specs: dict, n_micro: int, b_micro: int) -> dict:
    out = {}
    for k, v in specs.items():
        out[k] = jax.ShapeDtypeStruct((n_micro, b_micro) + v.shape[1:],
                                      v.dtype)
    return out


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh):
    """Returns (jitted_fn, example_args (ShapeDtypeStructs), meta).

    ``meta["static_bytes_per_device"]`` is the exact per-device footprint of
    params (+opt state / cache) under the chosen shardings;
    ``meta["analytic_peak_bytes"]`` adds the remat-aware activation model —
    the memory figure we stand behind for the v5e 16 GB fit, since the CPU
    backend's memory_analysis() includes layout copies a TPU build fuses
    away (EXPERIMENTS.md section Dry-run).
    """
    shard = make_shard_fn(mesh)
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(
        lambda k: init_params(cfg, k, PARAM_DTYPE), key)
    cache_shapes = c_specs = None
    cache_bytes = 0
    if shape.kind == "decode":
        cache_shapes = jax.eval_shape(
            lambda: init_decode_cache(cfg, shape.global_batch,
                                      shape.seq_len, PARAM_DTYPE))
        c_specs = cache_pspecs(cache_shapes, mesh, shape.global_batch)
        cache_bytes = H.sharded_bytes(cache_shapes, c_specs, mesh)
    profile = _profile_for(params_shapes, shape, mesh, cache_bytes)
    p_specs = param_pspecs(params_shapes, mesh, profile)
    p_sh = named_sharding_tree(p_specs, mesh)
    static_bytes = H.sharded_bytes(params_shapes, p_specs, mesh)

    if shape.kind == "train":
        n_micro, b_micro = microbatching(cfg, shape, mesh)
        opt_cfg = OptConfig(quantize_moments=count_params(cfg) > 3e10)
        opt_shapes = jax.eval_shape(
            lambda p: init_opt_state(p, opt_cfg), params_shapes)
        o_specs = opt_pspecs(opt_shapes, mesh)
        o_sh = named_sharding_tree(o_specs, mesh)
        bspecs = _with_micro_axis(
            batch_specs(cfg, b_micro, shape.seq_len), n_micro, b_micro)
        b_sh = _batch_shardings(bspecs, mesh, micro_axis=True)
        fn = make_train_step(cfg, opt_cfg, shard=shard, remat=True)
        jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                      out_shardings=(p_sh, o_sh, None),
                      donate_argnums=(0, 1))
        args = (params_shapes, opt_shapes, bspecs)
        static_bytes += H.sharded_bytes(opt_shapes, o_specs, mesh)
        meta = {"n_micro": n_micro, "b_micro": b_micro,
                "quantized_opt": opt_cfg.quantize_moments}
    elif shape.kind == "prefill":
        bspecs = batch_specs(cfg, shape.global_batch, shape.seq_len)
        bspecs.pop("labels", None)
        bspecs.pop("mask", None)
        b_sh = _batch_shardings(bspecs, mesh, micro_axis=False)
        fn = make_prefill_step(cfg, shard=shard)
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh), out_shardings=None)
        args = (params_shapes, bspecs)
        meta = {}
    else:  # decode: one token against a seq_len KV cache
        b = shape.global_batch
        c_sh = named_sharding_tree(c_specs, mesh)
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        t_sh = _batch_shardings({"tokens": tok}, mesh,
                                micro_axis=False)["tokens"]
        fn = make_decode_step(cfg, shard=shard)
        if cfg.pos == "mrope":
            pos3 = jax.ShapeDtypeStruct((b, 1, 3), jnp.int32)
            p3_sh = _batch_shardings({"p": pos3}, mesh,
                                     micro_axis=False)["p"]
            jfn = jax.jit(lambda p, c, t, p3: fn(p, c, t, p3),
                          in_shardings=(p_sh, c_sh, t_sh, p3_sh),
                          out_shardings=(None, c_sh),
                          donate_argnums=(1,))
            args = (params_shapes, cache_shapes, tok, pos3)
        else:
            jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh),
                          out_shardings=(None, c_sh), donate_argnums=(1,))
            args = (params_shapes, cache_shapes, tok)
        static_bytes += H.sharded_bytes(cache_shapes, c_specs, mesh)
        meta = {"cache_len": shape.seq_len}
    meta["param_profile"] = profile
    meta["static_bytes_per_device"] = int(static_bytes)
    meta["analytic_peak_bytes"] = int(
        static_bytes + H.analytic_activation_bytes(cfg, shape, mesh, meta))
    return jfn, args, meta


def _profile_for(params_shapes, shape: ShapeSpec, mesh,
                 cache_bytes: int = 0) -> str:
    """Serving profile (Perf iteration 3): replicate weights over "data"
    when the model-sharded copy PLUS the sharded cache fits HBM — kills
    per-token FSDP weight all-gathers. Falls back to FSDP for archs that
    cannot fit (deepseek-671b, grok-314b, qwen-110b at 32k x 128 cache),
    recorded in the cell meta."""
    if shape.kind not in ("decode", "prefill"):
        return "train"
    serve_specs = param_pspecs(params_shapes, mesh, profile="serve")
    w = H.sharded_bytes(params_shapes, serve_specs, mesh)
    return "serve" if w + cache_bytes < 13e9 else "train"


def build_layer_probe(cfg: ArchConfig, shape: ShapeSpec, mesh):
    """Lower ONE scan-period of the layer stack (fwd+bwd for train) under
    the production shardings.

    Why: ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so the
    scanned layer stack (and the microbatch loop) are invisible in the main
    step's flops. We therefore measure the per-period cost from this probe
    and compose the true step cost with known static trip counts
    (EXPERIMENTS.md section Roofline methodology).
    """
    groups = layer_groups(cfg)
    if not groups.n_periods or cfg.enc_dec:
        return None
    shard = make_shard_fn(mesh)
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(
        lambda k: init_params(cfg, k, PARAM_DTYPE), key)
    cache_bytes = 0
    if shape.kind == "decode":
        full_cache = jax.eval_shape(
            lambda: init_decode_cache(cfg, shape.global_batch,
                                      shape.seq_len, PARAM_DTYPE))
        cache_bytes = H.sharded_bytes(
            full_cache, cache_pspecs(full_cache, mesh, shape.global_batch),
            mesh)
    profile = _profile_for(params_shapes, shape, mesh, cache_bytes)
    slots = [jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), slot)
        for slot in params_shapes["body"]]
    slot_specs = [param_pspecs(s, mesh, profile) for s in slots]
    slot_sh = [named_sharding_tree(s, mesh) for s in slot_specs]

    if shape.kind == "train":
        n_micro, b_micro = microbatching(cfg, shape, mesh)
        b, s = b_micro, shape.seq_len
    elif shape.kind == "prefill":
        b, s = shape.global_batch, shape.seq_len
    else:
        b, s = shape.global_batch, 1
    x = jax.ShapeDtypeStruct((b, s, cfg.d_model), PARAM_DTYPE)
    pos_shape = (b, s, 3) if cfg.pos == "mrope" else (b, s)
    pos = jax.ShapeDtypeStruct(pos_shape, jnp.int32)
    x_sh = _batch_shardings({"x": x, "pos": pos}, mesh, micro_axis=False)

    if shape.kind == "decode":
        cache_shapes = jax.eval_shape(
            lambda: init_decode_cache(cfg, b, shape.seq_len, PARAM_DTYPE))
        slot_caches = [jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), c)
            for c in cache_shapes["body"]]
        c_specs = [cache_pspecs(c, mesh, b) for c in slot_caches]
        c_sh = [named_sharding_tree(s, mesh) for s in c_specs]

        def probe(slots, caches, x, pos):
            for si, kind in enumerate(groups.period):
                x, _ = apply_layer(slots[si], x, cfg, kind, pos=pos,
                                   cache=caches[si], shard=shard)
            return x

        jfn = jax.jit(probe, in_shardings=(slot_sh, c_sh, x_sh["x"],
                                           x_sh["pos"]))
        return jfn, (slots, slot_caches, x, pos)

    def fwd(slots, x, pos):
        h = x
        for si, kind in enumerate(groups.period):
            h, _ = apply_layer(slots[si], h, cfg, kind, pos=pos,
                               shard=shard)
        return jnp.sum(h.astype(jnp.float32))

    if shape.kind == "train":
        fwd_ck = jax.checkpoint(fwd)

        def probe(slots, x, pos):
            return jax.value_and_grad(fwd_ck, argnums=(0, 1))(slots, x, pos)
    else:
        probe = fwd
    jfn = jax.jit(probe, in_shardings=(slot_sh, x_sh["x"], x_sh["pos"]))
    return jfn, (slots, x, pos)


def _cost_of(lowered_compiled) -> tuple[dict, dict]:
    cost = lowered_compiled.cost_analysis()
    cost = dict(cost[0]) if isinstance(cost, (list, tuple)) else dict(cost)
    coll = H.collective_bytes(lowered_compiled.as_text())
    return cost, coll


def compose_costs(cfg: ArchConfig, shape: ShapeSpec, mesh, meta,
                  cost1: dict, coll1: dict,
                  cost3: dict | None, coll3: dict | None) -> tuple[dict, dict]:
    """Trip-count-corrected per-device cost (see build_layer_probe).

    train:   total = n_micro*(F1 - opt) + opt
                     + n_micro*(n_periods-1)*F3 + CE-chunk correction
    serve:   total = F1 + (n_periods-1)*F3
    Analytic opt term: flops ~20/param, bytes ~2x resident state. The rwkv
    inner time-scan body is counted once inside F3 (elementwise state ops,
    <2% of layer flops — documented undercount).
    """
    chips = int(np.prod(list(mesh.shape.values())))
    groups = layer_groups(cfg)
    f1 = float(cost1.get("flops", 0.0))
    b1 = float(cost1.get("bytes accessed", 0.0))
    c1 = float(coll1["total_bytes"])
    f3 = float(cost3.get("flops", 0.0)) if cost3 else 0.0
    b3 = float(cost3.get("bytes accessed", 0.0)) if cost3 else 0.0
    c3 = float(coll3["total_bytes"]) if coll3 else 0.0

    if shape.kind == "train":
        n_micro = meta["n_micro"]
        n_rep = max(groups.n_periods - 1, 0)
        n_params = count_params(cfg)
        opt_f = 20.0 * n_params / chips
        opt_b = 2.0 * meta["static_bytes_per_device"]
        seq = min(shape.seq_len, cfg.max_target_len) if cfg.enc_dec \
            else shape.seq_len
        n_chunks = max(1, seq // 512)
        v_sh = cfg.vocab if cfg.vocab % mesh.shape.get("model", 1) else \
            cfg.vocab // mesh.shape.get("model", 1)
        ce_f = 6.0 * meta["b_micro"] * (seq / n_chunks) * cfg.d_model * \
            cfg.vocab / chips * (n_chunks - 1) * n_micro
        ce_b = (n_chunks - 1) * n_micro * 3.0 * meta["b_micro"] / chips * \
            (seq / n_chunks) * v_sh * 4.0
        flops = n_micro * max(f1 - opt_f, 0) + opt_f \
            + n_micro * n_rep * f3 + ce_f
        byts = n_micro * max(b1 - opt_b, 0) + opt_b \
            + n_micro * n_rep * b3 + ce_b
        cbytes = n_micro * c1 + n_micro * n_rep * c3
    else:
        n_rep = max(groups.n_periods - 1, 0)
        flops = f1 + n_rep * f3
        byts = b1 + n_rep * b3
        cbytes = c1 + n_rep * c3
    return ({"flops": flops, "bytes accessed": byts},
            {"total_bytes": cbytes,
             "per_kind_bytes": coll1.get("per_kind_bytes", {}),
             "counts": coll1.get("counts", {})})


def model_flops_global(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """6*N*D for train (N = active params, D = tokens/step); 2*N*D for one
    decoded token per sequence; 2*N*D over prompt tokens for prefill."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        seq = min(shape.seq_len, cfg.max_target_len) if cfg.enc_dec \
            else shape.seq_len
        return 6.0 * n_active * shape.global_batch * seq
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/seq


def run_cell(arch: str, shape_name: str, mesh_name: str,
             force: bool = False) -> dict:
    os.makedirs(OUT_DIR, exist_ok=True)
    out_path = os.path.join(OUT_DIR, f"{mesh_name}__{arch}__{shape_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}

    ok, reason = applicable(cfg, shape)
    if not ok:
        record.update({"status": "skipped", "reason": reason})
        with open(out_path, "w") as f:
            json.dump(record, f, indent=1)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = int(np.prod(list(mesh.shape.values())))
    try:
        t0 = time.time()
        jfn, args, meta = build_cell(cfg, shape, mesh)
        with mesh:
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
        mem = H.memory_summary(compiled)
        print(f"[{mesh_name}|{arch}|{shape_name}] memory_analysis:", mem)
        cost1, coll1 = _cost_of(compiled)
        print(f"[{mesh_name}|{arch}|{shape_name}] cost_analysis(raw): "
              f"flops={cost1.get('flops', 0):.3e} "
              f"bytes={cost1.get('bytes accessed', 0):.3e}")

        cost3 = coll3 = None
        probe = build_layer_probe(cfg, shape, mesh)
        if probe is not None:
            pfn, pargs = probe
            with mesh:
                pcompiled = pfn.lower(*pargs).compile()
            cost3, coll3 = _cost_of(pcompiled)
        cost, coll = compose_costs(cfg, shape, mesh, meta,
                                   cost1, coll1, cost3, coll3)
        terms = H.roofline(cost, coll, chips=chips,
                           model_flops_global=model_flops_global(cfg, shape))
        record.update({
            "status": "ok",
            "chips": chips,
            "meta": meta,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": mem,
            "cost_raw": {k: float(v) for k, v in cost1.items()
                         if isinstance(v, (int, float))},
            "cost_probe": ({k: float(v) for k, v in cost3.items()
                            if isinstance(v, (int, float))}
                           if cost3 else None),
            "cost_corrected": cost,
            "collectives": coll,
            "roofline": terms.to_dict(),
            "param_count": count_params(cfg),
            "active_param_count": cfg.active_param_count(),
        })
    except Exception as e:
        record.update({"status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
        print(f"[{mesh_name}|{arch}|{shape_name}] FAILED: {e}")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ALL_ARCHS + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ALL_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    results = []
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                r = run_cell(arch, shape_name, mesh_name, force=args.force)
                status = r.get("status")
                dom = r.get("roofline", {}).get("dominant", "-")
                print(f"{mesh_name:8s} {arch:22s} {shape_name:12s} "
                      f"{status:8s} dominant={dom}")
                results.append(r)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = len(results) - n_ok - n_skip
    print(f"\ndry-run cells: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
