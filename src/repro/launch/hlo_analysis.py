"""Compiled-artifact analysis: collective bytes + roofline terms.

``compiled.as_text()`` is the post-SPMD, per-device optimized HLO module:
shapes on collective ops are per-device shapes. We sum result-operand sizes
for every collective op (async ``-start`` variants counted once, ``-done``
skipped). ``cost_analysis()`` flops/bytes are likewise per-device for the
single SPMD program; the global figures in the brief's formulas are
per-device x chips, so the chips factor cancels — we record both.

Hardware constants (TPU v5e-class, from the brief):
  197 TFLOP/s bf16 per chip | 819 GB/s HBM | ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\],{}\s]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Per-device bytes moved by collectives, by op kind + total."""
    per_kind: dict[str, int] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(
            kind)[0]
        sizes = [_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs)]
        nbytes = max(sizes) if sizes else 0
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
        counts[kind] = counts.get(kind, 0) + 1
    return {
        "per_kind_bytes": per_kind,
        "counts": counts,
        "total_bytes": sum(per_kind.values()),
    }


@dataclasses.dataclass
class RooflineTerms:
    """All terms in seconds-per-step (per chip)."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops: float            # 6*N*D (train) or 2*N*D per token (decode)
    useful_flops_ratio: float     # model_flops_per_device / HLO flops

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def to_dict(self) -> dict:
        return {**dataclasses.asdict(self), "dominant": self.dominant}


def roofline(cost: dict, coll: dict, *, chips: int,
             model_flops_global: float) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll["total_bytes"])
    mf_dev = model_flops_global / chips
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=cbytes / ICI_BW,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=cbytes,
        model_flops=model_flops_global,
        useful_flops_ratio=(mf_dev / flops) if flops else 0.0,
    )


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        keys = ["argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes"]
        out = {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
        args = out.get("argument_size_in_bytes", 0)
        alias = out.get("alias_size_in_bytes", 0)
        temp = out.get("temp_size_in_bytes", 0)
        outb = out.get("output_size_in_bytes", 0)
        out["peak_bytes_estimate"] = args + temp + max(outb - alias, 0)
        return out
    except Exception as e:  # backend without memory_analysis
        return {"error": f"{type(e).__name__}: {e}"}


def sharded_bytes(shapes_tree, specs_tree, mesh) -> int:
    """Exact per-device bytes of a ShapeDtypeStruct tree under specs."""
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec

    total = 0
    flat_shapes = jax.tree.leaves(shapes_tree)
    flat_specs = jax.tree.leaves(
        specs_tree, is_leaf=lambda x: isinstance(x, PartitionSpec))
    for sh, spec in zip(flat_shapes, flat_specs):
        n = int(np.prod(sh.shape)) if sh.shape else 1
        denom = 1
        for ax in spec:
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                denom *= mesh.shape[a]
        total += n * sh.dtype.itemsize // max(denom, 1)
    return total


def analytic_activation_bytes(cfg, shape, mesh, meta) -> int:
    """Per-device activation watermark under per-layer remat:
    layer-boundary checkpoints + one layer's live intermediates + CE chunk.
    """
    import numpy as np

    baxes = [a for a in ("pod", "data") if a in mesh.axis_names]
    n_b = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    if shape.kind == "train":
        b_local = max(1, meta.get("b_micro", shape.global_batch) // n_b)
    else:
        b_local = max(1, shape.global_batch // n_b)
    seq = min(shape.seq_len, cfg.max_target_len) if cfg.enc_dec \
        else shape.seq_len
    if shape.kind == "decode":
        seq = 1
    d = cfg.d_model
    resid = b_local * seq * d * 2                       # bf16 checkpoints
    ckpts = cfg.n_layers * resid if shape.kind == "train" else 2 * resid
    # one live layer: qkv + attn logits (n_heads/model-sharded if divisible)
    n_m = mesh.shape.get("model", 1)
    h_shard = cfg.n_heads // n_m if cfg.n_heads % n_m == 0 else cfg.n_heads
    live = 4 * resid + b_local * h_shard * seq * min(seq, 4096) * 4
    ce = 0
    if shape.kind == "train":
        chunk = min(seq, 512)
        v_shard = cfg.vocab // n_m if cfg.vocab % n_m == 0 else cfg.vocab
        ce = b_local * chunk * v_shard * 4 * 2          # logits + grad
    return int(ckpts + live + ce)
