# intentionally empty: dryrun.py must set XLA_FLAGS before jax ever loads,
# so nothing here may import jax (or any repro module that does).
