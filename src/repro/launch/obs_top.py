"""obs_top — a curses-free live view over the telemetry registry
(DESIGN.md section 12).

Renders a periodically-refreshing text dashboard of the serving stack:
process-wide QPS / end-to-end p50/p99 / queue depth / batch occupancy
(from the ``serve`` component of the unified registry) plus the
per-tenant SLO table (requests, outcome mix, attainment, burn rate,
latency percentiles from ``repro.obs.slo``). No curses — each frame is a
plain text block, with an ANSI home+clear prefix when stdout is a TTY
and nothing but a separator otherwise, so it pipes and logs cleanly.

The registry is in-process state, so ``obs_top`` is a *library* view:
call :func:`render` (one frame as a string) or :func:`run` (the refresh
loop) from the process that is serving. The module entrypoint wraps that
in a self-contained demo — ``--demo`` drives a small seeded trace
through a ``NeighborService`` on a background thread while the view
refreshes — which is also the CI smoke:

  PYTHONPATH=src python -m repro.launch.obs_top --demo --frames 3

``--frames N`` bounds the run (0 = until interrupted); ``--interval``
sets the refresh period; ``--openmetrics`` prints one OpenMetrics scrape
instead of the table (the same numbers, machine-readable).
"""
from __future__ import annotations

import argparse
import sys
import time


def _serve_row(metrics: dict, name: str) -> dict:
    return metrics.get(name, {})


def render(prev: dict | None = None, now: float | None = None) -> tuple:
    """One dashboard frame. Returns ``(text, state)``; pass ``state``
    back as ``prev`` on the next call so rate-style numbers (QPS) are
    per-interval deltas rather than lifetime means."""
    from repro import obs
    from repro.obs import slo

    t = time.monotonic() if now is None else float(now)
    serve = obs.REGISTRY.aggregate().get("serve", {})
    requests = _serve_row(serve, "requests").get("value", 0.0)
    resolved = _serve_row(serve, "resolved").get("value", 0.0)
    state = {"t": t, "requests": requests, "resolved": resolved}

    if prev:
        dt = max(t - prev["t"], 1e-9)
        qps = (requests - prev["requests"]) / dt
        rps = (resolved - prev["resolved"]) / dt
    else:
        qps = rps = 0.0

    lat = _serve_row(serve, "request_s")
    occ = _serve_row(serve, "batch_occupancy")
    lines = [
        "== repro obs_top ==",
        f"serve: {requests:.0f} admitted ({qps:.1f} req/s), "
        f"{resolved:.0f} resolved ({rps:.1f}/s), "
        f"{_serve_row(serve, 'batches').get('value', 0):.0f} batches",
        f"queue: depth={_serve_row(serve, 'queue_depth').get('value', 0):.0f}"
        f" rows={_serve_row(serve, 'queue_queries').get('value', 0):.0f}"
        f"  e2e p50={lat.get('p50', 0.0) * 1e3:.2f}ms"
        f" p99={lat.get('p99', 0.0) * 1e3:.2f}ms"
        f"  occupancy p50={occ.get('p50', 0.0):.2f}",
        slo.summary(),
    ]
    return "\n".join(lines), state


def run(interval_s: float = 1.0, frames: int = 0,
        out=None) -> int:
    """The refresh loop: render every ``interval_s`` until ``frames``
    frames have printed (0 = forever) or KeyboardInterrupt."""
    out = sys.stdout if out is None else out
    clear = "\x1b[2J\x1b[H" if out.isatty() else ""
    prev = None
    n = 0
    try:
        while True:
            frame, prev = render(prev)
            if clear:
                out.write(clear + frame + "\n")
            else:
                out.write(frame + "\n--\n")
            out.flush()
            n += 1
            if frames and n >= frames:
                return 0
            time.sleep(interval_s)
    except KeyboardInterrupt:
        return 0


def _demo_load(stop):
    """A tiny seeded serving workload (the ``--demo`` traffic source)."""
    import numpy as np

    from repro.core import SearchParams
    from repro.serve import NeighborService, ServeOpts

    rng = np.random.default_rng(0)
    svc = NeighborService(ServeOpts(max_wait_s=1e-3))
    for i in range(2):
        svc.register_scene(f"scene{i}",
                           rng.random((1200, 3)).astype(np.float32))
    params = SearchParams(radius=0.1, k=8, knn_window="exact")
    svc.start()
    try:
        while not stop.is_set():
            sid = f"scene{int(rng.integers(2))}"
            fut = svc.submit(sid, rng.random((16, 3)).astype(np.float32),
                             params)
            fut.result(timeout=30.0)
    finally:
        svc.stop()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--interval", type=float, default=1.0,
                    help="refresh period in seconds")
    ap.add_argument("--frames", type=int, default=0,
                    help="frames to print before exiting (0 = forever)")
    ap.add_argument("--demo", action="store_true",
                    help="drive a small seeded serving workload in the "
                         "background so the view has live numbers")
    ap.add_argument("--openmetrics", action="store_true",
                    help="print one OpenMetrics scrape and exit")
    args = ap.parse_args(argv)

    stop = t = None
    if args.demo:
        import threading
        stop = threading.Event()
        t = threading.Thread(target=_demo_load, args=(stop,),
                             name="obs-top-demo", daemon=True)
        t.start()
        time.sleep(min(args.interval, 0.5))   # let the first batches land
    try:
        if args.openmetrics:
            from repro import obs
            sys.stdout.write(obs.export_openmetrics())
            return 0
        return run(args.interval, args.frames)
    finally:
        if stop is not None:
            stop.set()
            # wait out an in-flight compile: tearing the process down
            # under a live XLA compile aborts noisily
            t.join(timeout=60.0)


if __name__ == "__main__":
    sys.exit(main())
