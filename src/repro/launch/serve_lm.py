"""LM serving demo (seed scaffold): batched greedy generation with a KV
cache. Moved out of ``repro.launch.serve`` — that module is now the
neighbor-search service driver; this demo stays reachable via
``python -m repro.launch.serve_lm`` (or ``...serve --lm``).

  PYTHONPATH=src python -m repro.launch.serve_lm --arch lm-100m \
      --requests 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro import obs
    from repro.configs import smoke_config
    from repro.models.config import get_config
    from repro.models.model import init_params
    from repro.train.serve_step import greedy_generate

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    prompts = jax.random.randint(
        key, (args.requests, args.prompt_len), 0, cfg.vocab, jnp.int32)
    cache_len = args.prompt_len + args.max_new + 1
    n_tok = args.requests * args.max_new
    metrics = obs.metric_set("serve_lm")

    # warmup pass: pays tracing + XLA compilation (and is reported as
    # such); the second identical-shape call hits the jit cache, so its
    # timing is the steady-state serving throughput
    with obs.span("warmup", arch=cfg.name) as sp_warm:
        out = jax.block_until_ready(
            greedy_generate(params, cfg, prompts, args.max_new, cache_len))
    with obs.span("generate", arch=cfg.name) as sp_gen:
        out = jax.block_until_ready(
            greedy_generate(params, cfg, prompts, args.max_new, cache_len))
    metrics.observe("warmup_s", sp_warm.duration)
    metrics.observe("generate_s", sp_gen.duration)
    metrics.count("tokens", 2 * n_tok)
    print(f"arch={cfg.name} generated {out.shape} tokens: "
          f"{n_tok / sp_gen.duration:.1f} tok/s steady-state, "
          f"{n_tok / sp_warm.duration:.1f} tok/s incl. compile "
          f"(warmup {sp_warm.duration:.2f}s)")
    print(out[:, :16])
    if obs.trace_enabled():
        print(obs.summary())


if __name__ == "__main__":
    main()
