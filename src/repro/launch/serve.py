"""Serving driver: batched greedy generation with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch lm-100m --requests 4 \
      --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import smoke_config
    from repro.models.config import get_config
    from repro.models.model import init_params
    from repro.train.serve_step import greedy_generate

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    prompts = jax.random.randint(
        key, (args.requests, args.prompt_len), 0, cfg.vocab, jnp.int32)
    cache_len = args.prompt_len + args.max_new + 1
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, prompts, args.max_new, cache_len)
    dt = time.perf_counter() - t0
    n_tok = args.requests * args.max_new
    print(f"arch={cfg.name} generated {out.shape} tokens "
          f"({n_tok / dt:.1f} tok/s incl. compile)")
    print(out[:, :16])


if __name__ == "__main__":
    main()
