"""Neighbor-search serving driver: a synthetic multi-tenant request trace
against ``repro.serve.NeighborService`` (DESIGN.md section 10).

Generates a seeded trace — N scenes, Poisson arrivals, per-request scene
ids drawn from a skewed tenant mix, mixed radii/K signatures, variable
query counts — drives it through the admission queue/micro-batcher, and
reports QPS, batch occupancy, and end-to-end p50/p95/p99 latency from the
unified telemetry registry.

  PYTHONPATH=src python -m repro.launch.serve --scenes 3 --requests 200
  PYTHONPATH=src python -m repro.launch.serve --smoke
  PYTHONPATH=src python -m repro.launch.serve --lm --smoke   # LM demo

The trace is deterministic per ``--seed`` (arrival process included), so
two runs drain identical batch sequences — the property the serve tests
pin down.

**Chaos mode** (DESIGN.md section 11): with ``REPRO_FAULTS`` set (e.g.
``REPRO_FAULTS=launch:0.2,straggler:0.1``) the same trace runs under
seeded fault injection. The driver then acts as the reliability gate: it
accounts every submitted request to exactly one terminal outcome
({result, DeadlineExceeded, QueryError, Rejected, CircuitOpen, ...}),
prints the outcome and injected-fault tables, and exits nonzero if ANY
future hangs (fails to resolve within the timeout) or goes unaccounted.

  REPRO_FAULTS=launch:0.2,straggler:0.1 \\
      PYTHONPATH=src python -m repro.launch.serve --trace short

``--trace short|full`` selects a canned trace size (short == the CI chaos
smoke); ``--deadline-ms`` arms per-request server-side deadlines on the
simulated arrival clock.

**Per-tenant SLOs** (DESIGN.md section 12): the driver always prints the
per-tenant outcome table from ``repro.obs.slo`` (every terminal outcome
is attributed by the service), and with a target armed — ``--slo
'latency_ms:250,objective:0.9'`` or the ``REPRO_SLO`` knob — it exits
nonzero if any tenant's attainment on the seeded trace is below its
objective. Hung futures additionally dump the flight recorder
(``REPRO_FLIGHT=1``) before the gate fails.
"""
from __future__ import annotations

import argparse
import sys
import time


def build_trace(args):
    """The seeded synthetic trace: (arrival_dt_s, scene_id, params,
    queries) per request, plus the per-scene point clouds."""
    import numpy as np

    from repro.core import SearchParams

    rng = np.random.default_rng(args.seed)
    scenes = {
        f"scene{i}": rng.random((args.points, 3)).astype(np.float32)
        for i in range(args.scenes)
    }
    # mixed search signatures: the micro-batcher buckets by these
    signatures = [
        SearchParams(radius=0.09, k=8, knn_window="exact"),
        SearchParams(radius=0.13, k=4, knn_window="exact"),
        SearchParams(radius=0.11, k=16, knn_window="exact"),
    ][: max(1, args.signatures)]
    # skewed tenant popularity (hot first scene), normalized
    weights = np.array([1.0 / (i + 1) for i in range(args.scenes)])
    weights /= weights.sum()
    scene_ids = list(scenes)
    trace = []
    for _ in range(args.requests):
        dt = float(rng.exponential(1.0 / args.rate))
        sid = scene_ids[int(rng.choice(args.scenes, p=weights))]
        params = signatures[int(rng.integers(len(signatures)))]
        nq = int(rng.integers(args.qmin, args.qmax + 1))
        q = rng.random((nq, 3)).astype(np.float32)
        trace.append((dt, sid, params, q))
    return scenes, signatures, trace


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--lm", action="store_true",
                    help="run the LM generation demo (repro.launch."
                         "serve_lm) instead of the neighbor service")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", choices=("short", "full"), default=None,
                    help="canned trace size: 'short' (the CI chaos smoke) "
                         "or 'full' (the default-size trace)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request server-side deadline on the simulated "
                         "arrival clock (0 = none)")
    ap.add_argument("--slo", default=None, metavar="SPEC",
                    help="arm a per-tenant SLO target (e.g. "
                         "'latency_ms:250,objective:0.9'); the gate exits "
                         "nonzero if any tenant's attainment falls below "
                         "its objective (default: the REPRO_SLO knob)")
    ap.add_argument("--scenes", type=int, default=3)
    ap.add_argument("--signatures", type=int, default=2,
                    help="distinct (radius, K) request signatures in the mix")
    ap.add_argument("--points", type=int, default=4000)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="Poisson arrival rate (requests/s of trace time)")
    ap.add_argument("--qmin", type=int, default=8)
    ap.add_argument("--qmax", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--max-wait-ms", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=0)
    args, rest = ap.parse_known_args(argv)

    if args.lm:
        from . import serve_lm
        return serve_lm.main(rest + (["--smoke"] if args.smoke else []))
    if rest:
        ap.error(f"unrecognized arguments: {' '.join(rest)}")
    if args.trace == "short":
        args.smoke = True
    if args.smoke:
        args.scenes, args.points = min(args.scenes, 2), 1200
        args.requests, args.qmax = 64, 32

    from repro import obs
    from repro.obs import flight, slo
    from repro.reliability import faults
    from repro.serve import (CircuitOpen, NeighborService, QueryError,
                             Rejected, ServeOpts)

    if args.slo:
        slo.configure(slo.SLOTarget.parse(args.slo))

    opts = ServeOpts(
        max_batch=args.max_batch,
        max_wait_s=(args.max_wait_ms / 1e3
                    if args.max_wait_ms is not None else None),
        deadline_s=args.deadline_ms / 1e3)
    svc = NeighborService(opts)
    scenes, signatures, trace = build_trace(args)
    # register + warm every (scene, signature) variant at the common
    # launch bucket, so steady-state latency (not jit compiles) is what
    # the trace measures — a real serving process warms at admission too
    t_warm0 = time.perf_counter()
    for sid, pts in scenes.items():
        svc.register_scene(sid, pts)
        for params in signatures:
            svc.registry.get(sid).variant(params).warm(args.qmax)
    print(f"serve: warmed {len(scenes)}x{len(signatures)} scene variants "
          f"in {time.perf_counter() - t_warm0:.1f}s")

    # drive the trace on a simulated arrival clock: submit each request at
    # its arrival time, pumping whenever the bucket deadline has passed;
    # wall-clock (real) time is what QPS/latency are measured in. Every
    # submitted request is accounted to exactly ONE terminal outcome —
    # the reliability taxonomy the chaos gate asserts on.
    outcomes: dict[str, int] = {}

    def account(name):
        outcomes[name] = outcomes.get(name, 0) + 1

    futures, rejected = [], 0
    t_wall0 = time.perf_counter()
    now = 0.0
    for dt, sid, params, q in trace:
        now += dt
        try:
            futures.append((sid, svc.submit(
                sid, q, params, now=now,
                deadline_s=args.deadline_ms / 1e3 or None)))
        except Rejected:
            rejected += 1
            svc.pump(now=now, force=True)
            try:
                futures.append((sid, svc.submit(
                    sid, q, params, now=now,
                    deadline_s=args.deadline_ms / 1e3 or None)))
            except (Rejected, CircuitOpen, QueryError) as exc:
                account(type(exc).__name__)
        except (CircuitOpen, QueryError) as exc:
            account(type(exc).__name__)
        svc.pump(now=now)
    reports = svc.drain(now=now)
    wall = time.perf_counter() - t_wall0

    # the zero-hung-futures gate: every admitted future must resolve —
    # a TimeoutError here means a request was stranded, the one failure
    # mode the reliability layer promises cannot happen
    hung = 0
    for _sid, f in futures:
        try:
            f.result(timeout=60.0)
            if f.quality is not None and f.quality.reduced_ladder:
                account("degraded")
            else:
                account("result")
        except TimeoutError:
            hung += 1
            account("HUNG")
        except Exception as exc:
            account(type(exc).__name__)

    st = svc.stats()
    n = len(futures)
    occ = sum(r.nq for r in reports) / max(
        sum(r.pad_n for r in reports), 1)
    snap = svc._metrics.snapshot().get("request_s", {})
    pct = {k: snap.get(k, 0.0) for k in ("p50", "p95", "p99")}
    print(f"serve: {n} requests over {len(scenes)} scenes -> "
          f"{st['batches']} batches ({st['host_syncs']} host syncs), "
          f"{n / wall:.1f} req/s, occupancy {occ:.2f}, "
          f"{rejected} rejected")
    print(f"serve: e2e latency p50={pct['p50'] * 1e3:.2f}ms "
          f"p95={pct['p95'] * 1e3:.2f}ms p99={pct['p99'] * 1e3:.2f}ms")

    plan = faults.active()
    accounted = sum(outcomes.values())
    print("serve: outcomes " + ", ".join(
        f"{k}={v}" for k, v in sorted(outcomes.items())) +
        f" (accounted {accounted}/{len(trace)})")
    if plan is not None:
        inj = {k: v for k, v in plan.stats().items() if v}
        print(f"serve: chaos plan {plan.spec()} injected {inj or 'nothing'}"
              f", breakers {st['breakers'] or '{}'}"
              f", retries={st.get('retries', 0)}"
              f" stragglers={st.get('stragglers', 0)}"
              f" expired={st.get('expired', 0)}")
    # per-tenant outcome breakdown: every terminal outcome the service
    # attributed (ok/degraded/expired/rejected/circuit_open/error),
    # attainment and burn rate per tenant
    print(slo.summary())
    if obs.trace_enabled():
        print(obs.summary())
    if hung:
        # a hung future is THE reliability failure mode — capture the
        # post-mortem before the gate fails (no-op unless REPRO_FLIGHT=1)
        dumped = flight.dump("hung_futures")
        if dumped:
            print(f"serve: flight recorder dumped to {dumped}",
                  file=sys.stderr)
    fail = hung or accounted != len(trace)
    if fail:
        print(f"serve: FAILED — hung futures: {hung}, accounted "
              f"{accounted}/{len(trace)}", file=sys.stderr)
    viol = slo.violations()
    for tenant, (att, obj) in sorted(viol.items()):
        print(f"serve: SLO VIOLATION — tenant {tenant} attainment "
              f"{att:.3f} < objective {obj:.3f}", file=sys.stderr)
    return 1 if (fail or viol) else 0


if __name__ == "__main__":
    sys.exit(main())
