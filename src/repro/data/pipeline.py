"""Synthetic token pipeline: batches for every arch family.

``batch_specs`` returns ShapeDtypeStructs (dry-run path, no allocation);
``make_batch`` materializes a random batch with the same tree (tests,
examples, the 100M-train driver); ``synthetic_stream`` is the deterministic,
checkpoint-resumable training stream (the data cursor is a step index, so
restore = skip-free seek — fault-tolerance requirement).
"""
from __future__ import annotations

from functools import partial
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig


def _token_fields(batch: int, seq: int):
    return {
        "tokens": ((batch, seq), jnp.int32),
        "labels": ((batch, seq), jnp.int32),
        "mask": ((batch, seq), jnp.float32),
    }


def batch_specs(cfg: ArchConfig, batch: int, seq: int
                ) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    if cfg.enc_dec:
        seq = min(seq, cfg.max_target_len)
    fields = dict(_token_fields(batch, seq))
    if cfg.pos == "mrope":
        fields["pos3"] = ((batch, seq, 3), jnp.int32)
    if cfg.frontend == "vision_stub" and cfg.n_vision_tokens:
        fields["vision_embeds"] = (
            (batch, min(cfg.n_vision_tokens, seq), cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        fields["enc_input"] = ((batch, cfg.enc_context, cfg.d_model),
                               jnp.bfloat16)
    return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in fields.items()}


def make_batch(cfg: ArchConfig, batch: int, seq: int, key,
               dtype=jnp.float32) -> dict[str, jax.Array]:
    """Random batch with the same tree as ``batch_specs``."""
    if cfg.enc_dec:
        seq = min(seq, cfg.max_target_len)
    ks = jax.random.split(key, 4)
    tokens = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab, jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones((batch, seq), jnp.float32).at[:, -1].set(0.0)
    out = {"tokens": tokens, "labels": labels, "mask": mask}
    if cfg.pos == "mrope":
        # text tokens: all three position streams equal; vision stub tokens
        # get (t, h, w) grid positions
        nv = min(cfg.n_vision_tokens, seq) if cfg.frontend == "vision_stub" \
            else 0
        p = jnp.broadcast_to(jnp.arange(seq)[None, :, None],
                             (batch, seq, 3)).astype(jnp.int32)
        if nv:
            side = max(1, int(np.sqrt(nv)))
            hh = (jnp.arange(nv) // side).astype(jnp.int32)
            ww = (jnp.arange(nv) % side).astype(jnp.int32)
            vis = jnp.stack([jnp.zeros((nv,), jnp.int32), hh, ww], -1)
            p = p.at[:, :nv].set(vis[None])
        out["pos3"] = p
    if cfg.frontend == "vision_stub" and cfg.n_vision_tokens:
        nv = min(cfg.n_vision_tokens, seq)
        out["vision_embeds"] = jax.random.normal(
            ks[1], (batch, nv, cfg.d_model), jnp.float32).astype(dtype) * 0.02
        out["mask"] = out["mask"].at[:, :nv].set(0.0)  # no loss on vision
    if cfg.enc_dec:
        out["enc_input"] = jax.random.normal(
            ks[2], (batch, cfg.enc_context, cfg.d_model),
            jnp.float32).astype(dtype) * 0.02
    return out


def synthetic_stream(cfg: ArchConfig, batch: int, seq: int, *,
                     start_step: int = 0, seed: int = 0,
                     dtype=jnp.float32) -> Iterator[dict[str, jax.Array]]:
    """Deterministic resumable stream: batch at step s is a pure function of
    (seed, s), so checkpoint restore resumes exactly."""
    step = start_step
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        yield make_batch(cfg, batch, seq, key, dtype)
        step += 1
