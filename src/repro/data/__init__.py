from .pipeline import make_batch, synthetic_stream, batch_specs
from .pointclouds import (uniform_cloud, kitti_like_cloud, clustered_cloud,
                          dataset_by_name)
