"""Synthetic point-cloud generators mirroring the paper's three datasets.

The paper evaluates on KITTI LiDAR (points confined to a thin z-slab),
Stanford 3-D scans (uniform-ish surface samples), and Millennium N-body
(strongly clustered, fractal). We generate distribution-matched synthetic
clouds so every benchmark exercises the same regimes (this container has no
dataset downloads).
"""
from __future__ import annotations

import numpy as np


def uniform_cloud(n: int, seed: int = 0) -> np.ndarray:
    """Stanford-scan proxy: near-uniform points in the unit cube."""
    rng = np.random.default_rng(seed)
    return rng.random((n, 3), dtype=np.float32)


def kitti_like_cloud(n: int, seed: int = 0, z_range: float = 0.04
                     ) -> np.ndarray:
    """KITTI proxy: xy-plane spread with a narrow z slab (the paper notes
    the LiDAR points are 'confined in a very narrow z-range')."""
    rng = np.random.default_rng(seed)
    xy = rng.random((n, 2), dtype=np.float32)
    z = rng.random((n, 1), dtype=np.float32) * z_range
    # ring-like radial density falloff from the sensor, LiDAR-ish
    r = np.sqrt(rng.random((n, 1), dtype=np.float32))
    xy = 0.5 + (xy - 0.5) * r
    return np.concatenate([xy, z], axis=1).astype(np.float32)


def clustered_cloud(n: int, seed: int = 0, n_clusters: int = 64,
                    frac_background: float = 0.1) -> np.ndarray:
    """N-body proxy: hierarchically clustered (galaxy-like) distribution —
    the regime where the paper's partitioning over-fragments (Fig. 12/13
    NBody discussion)."""
    rng = np.random.default_rng(seed)
    n_bg = int(n * frac_background)
    n_cl = n - n_bg
    centers = rng.random((n_clusters, 3), dtype=np.float32)
    sizes = rng.pareto(2.0, n_clusters) + 0.2
    sizes = sizes / sizes.sum()
    counts = rng.multinomial(n_cl, sizes)
    chunks = [rng.normal(centers[i], 0.015 * (1 + sizes[i] * n_clusters / 4),
                         (c, 3)).astype(np.float32)
              for i, c in enumerate(counts) if c > 0]
    pts = np.concatenate(chunks + [rng.random((n_bg, 3), dtype=np.float32)])
    return np.clip(pts, 0.0, 1.0).astype(np.float32)


def dataset_by_name(name: str, n: int, seed: int = 0) -> np.ndarray:
    return {"kitti": kitti_like_cloud, "scan": uniform_cloud,
            "nbody": clustered_cloud}[name](n, seed)
