"""repro.serve — multi-tenant streaming neighbor-query service
(DESIGN.md section 10).

Layers a serving contract over the functional core and the device-resident
executor: a scene registry (LRU residency, per-signature compiled serve
programs), an admission queue with signature-bucket micro-batching (one
concatenated launch — and one host sync — per drained batch), futures with
bounded-queue backpressure, per-scene fairness, and full ``repro.obs``
telemetry (queue depth, batch occupancy, p50/p95/p99 request latency).

Quickstart::

    from repro.serve import NeighborService
    from repro.core import SearchParams

    svc = NeighborService()
    svc.register_scene("city", points)
    futs = [svc.submit("city", q, SearchParams(radius=0.1, k=8))
            for q in request_queries]
    svc.drain()                      # or svc.start() for a background pump
    results = [f.result() for f in futs]
"""
from ..reliability.errors import (Cancelled, CircuitOpen,  # noqa: F401
                                  DeadlineExceeded, QueryError)
from ..reliability.quality import ResultQuality  # noqa: F401
from .batcher import (BatchReport, MicroBatcher, Request,  # noqa: F401
                      StagedBatch, split_result, stage_batch)
from .registry import (SceneRecord, SceneRegistry,  # noqa: F401
                       SceneVariant)
from .service import (NeighborService, Rejected,  # noqa: F401
                      ServeFuture, ServeOpts)

__all__ = [
    "BatchReport",
    "Cancelled",
    "CircuitOpen",
    "DeadlineExceeded",
    "MicroBatcher",
    "NeighborService",
    "QueryError",
    "Rejected",
    "Request",
    "ResultQuality",
    "SceneRecord",
    "SceneRegistry",
    "SceneVariant",
    "ServeFuture",
    "ServeOpts",
    "StagedBatch",
    "split_result",
    "stage_batch",
]
