"""Admission queue + signature-bucket micro-batcher (DESIGN.md section 10).

In-flight requests from many logical clients are grouped by **bucket key**
``(scene id, SearchParams, SearchOpts)`` — the signature that determines
which compiled serve program a launch runs through — and drained as ONE
concatenated launch per bucket: the paper's coalescing lesson applied
across tenants instead of across a single caller's queries. Two knobs
bound the latency/throughput trade:

* ``max_batch`` — at most this many concatenated query rows drain per
  launch (whole requests only; an oversized single request drains alone),
  so throughput saturates with dense, bounded launches under heavy load;
* ``max_wait`` — a bucket becomes *due* once its oldest request has waited
  this long even if far from full, so latency is bounded under light load.

Drain order is deterministic given the submission order: buckets are
picked **round-robin over scenes** (per-scene fairness — a hot tenant
flooding one bucket cannot starve the others; its surplus waits for later
rounds) and FIFO within a scene and within a bucket. The drain loop is
**pipelined**: batch N+1 is staged (host concat/pad/upload) and dispatched
while batch N still executes on device, and only then is batch N synced —
the one blocking host sync per drained batch.
"""
from __future__ import annotations

import collections
import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from ..core.types import SearchOpts, SearchParams, SearchResult


@dataclasses.dataclass
class Request:
    """One admitted request: ``queries`` [nq, 3] against one scene under
    one search signature. ``seq`` is the admission sequence number (the
    total order every drain decision derives from). ``t_submit`` is the
    *scheduling* timestamp the bucket deadline ages against — simulated
    trace drivers may supply a virtual clock — while ``t_real`` is always
    the monotonic wall time latency metrics are measured from.

    ``deadline`` (same clock as ``t_submit``; None = no deadline) is the
    server-side expiry: a request past it is dropped at bucket drain,
    BEFORE launch, and its future fails with ``DeadlineExceeded``
    (DESIGN.md section 11). ``degraded`` marks requests admitted under
    the overload ladder cap (``ServeOpts.degrade``): they serve at a
    reduced window and their responses carry a degraded
    ``ResultQuality`` flag.

    ``trace_id`` is the request-scoped trace context (DESIGN.md section
    12): a process-unique ``req-NNNNNN`` id assigned at admission that
    every span touching this request carries — per-request spans as the
    top-level ``trace`` field, batch-granular spans in a ``trace_ids``
    attribute — so ``obs.timeline(trace_id)`` reconstructs the request's
    full admission-to-resolution story."""

    seq: int
    scene_id: object
    params: SearchParams
    opts: SearchOpts
    queries: np.ndarray
    future: object
    t_submit: float
    t_real: float
    deadline: float | None = None
    degraded: bool = False
    trace_id: str = ""

    @property
    def nq(self) -> int:
        return int(self.queries.shape[0])

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now >= self.deadline


@dataclasses.dataclass(frozen=True)
class BatchReport:
    """What one drained launch contained (returned by ``Service.pump`` —
    the deterministic drain-order record the tests assert on)."""

    scene_id: object
    params: SearchParams
    seqs: tuple
    nq: int
    pad_n: int


class _Bucket:
    __slots__ = ("key", "requests", "nq_total")

    def __init__(self, key):
        self.key = key
        self.requests: collections.deque = collections.deque()
        self.nq_total = 0

    def push(self, req: Request) -> None:
        self.requests.append(req)
        self.nq_total += req.nq

    @property
    def t_oldest(self) -> float:
        return self.requests[0].t_submit


class MicroBatcher:
    """The pending-request store: buckets by signature, fairness by scene."""

    def __init__(self):
        self._buckets: collections.OrderedDict = collections.OrderedDict()
        # per-scene FIFO of bucket keys with pending work + the round-robin
        # cursor over scene ids (fairness across tenants)
        self._scene_keys: collections.OrderedDict = collections.OrderedDict()
        self._rr: collections.deque = collections.deque()
        self.pending_requests = 0
        self.pending_queries = 0

    # -- admission ----------------------------------------------------------

    def add(self, req: Request) -> None:
        key = (req.scene_id, req.params, req.opts)
        bucket = self._buckets.get(key)
        if bucket is None:
            bucket = self._buckets[key] = _Bucket(key)
            keys = self._scene_keys.get(req.scene_id)
            if keys is None:
                keys = self._scene_keys[req.scene_id] = collections.deque()
                self._rr.append(req.scene_id)
            keys.append(key)
        bucket.push(req)
        self.pending_requests += 1
        self.pending_queries += req.nq

    def empty(self) -> bool:
        return not self._buckets

    def queue_depth(self) -> tuple[int, int]:
        return self.pending_requests, self.pending_queries

    def oldest_wait(self, now: float) -> float:
        """Age of the oldest pending request (0 when idle) — the scheduling
        statistic a background pump loop polls."""
        if not self._buckets:
            return 0.0
        return max(0.0, now - min(b.t_oldest
                                  for b in self._buckets.values()))

    def _retry_after(self, mean_batch_s: float | None, max_batch: int,
                     floor_s: float) -> float:
        """Retry-after estimate for a rejected admission: roughly how
        long until the current backlog has drained, from the mean recent
        drain time. Hardened for cold start (DESIGN.md section 11): before
        any drain has completed — or when the estimate is degenerate
        (zero, negative, NaN, inf) — the configured ``floor_s`` is
        returned instead of 0/NaN, so clients always get a usable
        positive backoff hint."""
        floor_s = max(float(floor_s), 1e-6)
        if (mean_batch_s is None or not math.isfinite(mean_batch_s)
                or mean_batch_s <= 0.0):
            mean_batch_s = floor_s
        backlog = self.pending_queries / max(int(max_batch), 1)
        est = mean_batch_s * max(backlog, 1.0)
        if not math.isfinite(est) or est <= 0.0:
            return floor_s
        return max(floor_s, est)

    # -- drain selection ----------------------------------------------------

    def _due(self, bucket: _Bucket, now: float, max_wait: float,
             max_batch: int, force: bool) -> bool:
        if force:
            return True
        return (bucket.nq_total >= max_batch
                or (now - bucket.t_oldest) >= max_wait)

    def take(self, now: float, *, max_wait: float, max_batch: int,
             force: bool = False) -> tuple[object, list[Request]] | None:
        """Pop the next due batch ``(bucket_key, requests)`` under the
        scene round-robin, or None when nothing is due.

        Takes whole requests FIFO up to ``max_batch`` query rows (at least
        one request always drains, so an oversized request still ships —
        alone). A bucket left non-empty keeps its queue position; the
        round-robin cursor advances past the drained scene either way.
        """
        for _ in range(len(self._rr)):
            scene_id = self._rr[0]
            self._rr.rotate(-1)
            keys = self._scene_keys[scene_id]
            for key in list(keys):
                bucket = self._buckets[key]
                if not self._due(bucket, now, max_wait, max_batch, force):
                    continue
                taken: list[Request] = []
                nq = 0
                while bucket.requests and (
                        not taken or nq + bucket.requests[0].nq <= max_batch):
                    req = bucket.requests.popleft()
                    bucket.nq_total -= req.nq
                    nq += req.nq
                    taken.append(req)
                if not bucket.requests:
                    del self._buckets[key]
                    keys.remove(key)
                    if not keys:
                        del self._scene_keys[scene_id]
                        self._rr.remove(scene_id)
                self.pending_requests -= len(taken)
                self.pending_queries -= nq
                return key, taken
        return None


@dataclasses.dataclass
class StagedBatch:
    """One batch after host staging: the concatenated, bucket-padded query
    upload plus the per-request split offsets."""

    key: object
    requests: list
    queries: jnp.ndarray          # [pad_n, 3] device
    offsets: list                 # len(requests)+1 prefix sums
    nq: int
    pad_n: int


def stage_batch(key, requests: list, pad_n: int) -> StagedBatch:
    """Concatenate the batch's query rows, edge-pad to the launch bucket
    (padded rows repeat the last real query — the executor's idempotent
    padding discipline), and upload. Pure host work: this is the stage the
    drain loop overlaps with the PREVIOUS batch's device execution."""
    arrays = [r.queries for r in requests]
    offsets = np.cumsum([0] + [a.shape[0] for a in arrays]).tolist()
    nq = offsets[-1]
    cat = np.concatenate(arrays, axis=0) if len(arrays) > 1 else arrays[0]
    if pad_n > nq:
        cat = np.concatenate(
            [cat, np.broadcast_to(cat[-1], (pad_n - nq, 3))], axis=0)
    return StagedBatch(key=key, requests=requests,
                       queries=jnp.asarray(cat, jnp.float32),
                       offsets=offsets, nq=nq, pad_n=pad_n)


def split_result(staged: StagedBatch, result: SearchResult) -> list:
    """Per-request ``SearchResult`` views of one drained launch's output
    (device slices — no host transfer)."""
    out = []
    for a, b in zip(staged.offsets[:-1], staged.offsets[1:]):
        out.append(SearchResult(indices=result.indices[a:b],
                                distances2=result.distances2[a:b],
                                counts=result.counts[a:b]))
    return out
