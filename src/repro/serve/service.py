"""Multi-tenant streaming neighbor-query service (DESIGN.md sections
10-11).

``NeighborService`` layers the serving contract over the functional core:

* ``submit(scene_id, queries, params)`` admits a request and returns a
  :class:`ServeFuture` resolved at drain time. Admission is bounded: past
  the ``max_pending`` high-water mark the queue **rejects with
  retry-after** (:class:`Rejected`) — or, with ``ServeOpts.degrade`` on,
  admits the request at a reduced ladder level and flags the response
  degraded (graceful degradation instead of rejection).
* ``pump()`` drains every *due* signature bucket (see ``batcher``) as one
  concatenated launch through the scene's variant-private compiled
  ``api.query`` program — ONE blocking host sync per drained batch — with
  the next batch staged and dispatched while the previous one executes
  (``pipeline`` in-flight batches; the dispatch-then-stage overlap).
* ``drain()`` pumps with the deadline forced until the queue is empty.
* ``start()/stop()`` run the pump on a background thread for real
  streaming callers; the synchronous surface stays fully deterministic for
  tests and the trace driver.

**Failure paths are first-class** (``repro.reliability``, DESIGN.md
section 11). Every admitted request resolves as exactly one of {result,
``QueryError``, ``DeadlineExceeded``, ``Rejected``, ``CircuitOpen``}
(plus ``Cancelled`` for caller-cancelled futures) — no future ever
hangs:

* inputs are validated at admission (``api.validate_queries``): NaN/inf/
  sentinel-colliding rows fail with a structured ``QueryError`` before
  they can poison a concatenated launch;
* per-request server-side deadlines: an expired request is dropped at
  bucket drain — BEFORE launch — and fails with ``DeadlineExceeded``
  (counted as ``serve.expired``); a caller-cancelled future is likewise
  dropped unlaunched, so a client that gave up cannot leak device work;
* transient launch failures retry with exponential backoff + jitter
  (bounded by ``ServeOpts.retries``);
* a per-scene **circuit breaker** (``reliability.breaker``) opens after
  ``breaker_n`` consecutive batch failures: the poisoned scene fails
  fast (``CircuitOpen`` at submit and drain) while every other tenant
  keeps draining; a half-open probe closes it once the scene recovers;
* the background pump thread is crash-contained: an escaped exception
  fails the in-flight futures, is counted (``serve.pump_restarts``),
  and the pump restarts instead of dying and hanging every future;
* every response carries :class:`~repro.reliability.ResultQuality`
  derived from the scene's device overflow/oob counters
  (``fut.quality``), so silently-truncated neighborhoods are flagged.

Every stage feeds the unified telemetry layer (``repro.obs``, component
``serve``): queue-depth gauges, batch-occupancy histograms, end-to-end
request latency percentiles, per-drain straggler detection (the shared
``train.fault_tolerance.StragglerMonitor``), and the host-sync counter
the one-sync contract is asserted against. ``obs.summary()`` over a
serving process reads as the service dashboard.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import os
import threading
import time

import jax
import numpy as np

from .. import obs
from ..obs import flight as flightrec
from ..obs import slo
from ..core import api
from ..core.types import SearchOpts, SearchParams, SearchResult
from ..reliability import faults
from ..reliability.breaker import CircuitBreaker
from ..reliability.errors import (Cancelled, CircuitOpen, DeadlineExceeded,
                                  QueryError, is_transient)
from ..reliability.quality import ResultQuality
from ..train.fault_tolerance import StragglerMonitor
from .batcher import BatchReport, MicroBatcher, Request, split_result, \
    stage_batch
from .registry import SceneRegistry


# request-scoped trace ids (DESIGN.md section 12): process-unique across
# service instances, so merged span streams never collide
_REQ_IDS = itertools.count(1)


def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def _env_float(name: str, default: float) -> float:
    return float(os.environ.get(name, default))


class ServeOpts:
    """Service knobs (env defaults, DESIGN.md section 4 ``REPRO_SERVE_*``
    / ``REPRO_DEADLINE_*``).

    ``max_pending``   admission high-water mark in pending *query rows*;
    ``max_batch``     max concatenated query rows per drained launch;
    ``max_wait_s``    bucket deadline — a request waits at most this long
                      before its bucket is due even if nearly empty
                      (``REPRO_SERVE_MAX_WAIT_MS`` is in milliseconds);
    ``pipeline``      in-flight launches the drain loop keeps before
                      syncing the oldest (0 = sync immediately after each
                      dispatch, i.e. no overlap);
    ``scenes``        registry capacity (resident scenes, LRU-evicted).

    Reliability (section 11):

    ``deadline_s``    default per-request server-side deadline
                      (``REPRO_DEADLINE_MS``; 0 = none — ``submit``'s
                      ``deadline_s`` overrides per request);
    ``retries``       bounded retry budget for transient launch failures;
    ``backoff_s``     base of the exponential backoff between retries
                      (jittered x0.5-1.5);
    ``breaker_n``     consecutive batch failures that open a scene's
                      circuit breaker;
    ``breaker_cooldown_s``  breaker cooldown before the half-open probe
                      (doubles on failed probes);
    ``retry_floor_s`` floor of the ``Rejected``/``CircuitOpen``
                      retry-after estimate (the cold-start hardening of
                      ``MicroBatcher._retry_after``);
    ``validate``      validate query inputs at admission
                      (``api.validate_queries`` -> ``QueryError``);
    ``degrade``       overload mode: past ``max_pending`` admit at the
                      reduced ``degrade_ladder`` (flagged degraded)
                      instead of rejecting, up to ``degrade_hard`` x
                      ``max_pending`` (past THAT, reject regardless);
    ``seed``          deterministic seed of the retry jitter.
    """

    __slots__ = ("max_pending", "max_batch", "max_wait_s", "pipeline",
                 "scenes", "deadline_s", "retries", "backoff_s",
                 "breaker_n", "breaker_cooldown_s", "retry_floor_s",
                 "validate", "degrade", "degrade_ladder", "degrade_hard",
                 "seed")

    def __init__(self, max_pending: int | None = None,
                 max_batch: int | None = None,
                 max_wait_s: float | None = None,
                 pipeline: int | None = None,
                 scenes: int | None = None,
                 deadline_s: float | None = None,
                 retries: int | None = None,
                 backoff_s: float | None = None,
                 breaker_n: int | None = None,
                 breaker_cooldown_s: float | None = None,
                 retry_floor_s: float | None = None,
                 validate: bool | None = None,
                 degrade: bool | None = None,
                 degrade_ladder: tuple = (1,),
                 degrade_hard: float = 2.0,
                 seed: int | None = None):
        self.max_pending = (_env_int("REPRO_SERVE_MAX_PENDING", 65536)
                            if max_pending is None else int(max_pending))
        self.max_batch = (_env_int("REPRO_SERVE_MAX_BATCH", 4096)
                          if max_batch is None else int(max_batch))
        self.max_wait_s = (
            _env_float("REPRO_SERVE_MAX_WAIT_MS", 2.0) / 1e3
            if max_wait_s is None else float(max_wait_s))
        self.pipeline = (_env_int("REPRO_SERVE_PIPELINE", 1)
                         if pipeline is None else int(pipeline))
        self.scenes = (_env_int("REPRO_SERVE_SCENES", 8)
                       if scenes is None else int(scenes))
        self.deadline_s = (_env_float("REPRO_DEADLINE_MS", 0.0) / 1e3
                           if deadline_s is None else float(deadline_s))
        self.retries = (_env_int("REPRO_SERVE_RETRIES", 2)
                        if retries is None else int(retries))
        self.backoff_s = (_env_float("REPRO_SERVE_BACKOFF_MS", 1.0) / 1e3
                          if backoff_s is None else float(backoff_s))
        self.breaker_n = (_env_int("REPRO_SERVE_BREAKER_N", 3)
                          if breaker_n is None else int(breaker_n))
        self.breaker_cooldown_s = (
            _env_float("REPRO_SERVE_BREAKER_COOLDOWN_MS", 50.0) / 1e3
            if breaker_cooldown_s is None else float(breaker_cooldown_s))
        self.retry_floor_s = (
            _env_float("REPRO_SERVE_RETRY_FLOOR_MS", 1.0) / 1e3
            if retry_floor_s is None else float(retry_floor_s))
        self.validate = (_env_int("REPRO_SERVE_VALIDATE", 1) != 0
                         if validate is None else bool(validate))
        self.degrade = (_env_int("REPRO_SERVE_DEGRADE", 0) != 0
                        if degrade is None else bool(degrade))
        self.degrade_ladder = tuple(int(w) for w in degrade_ladder)
        self.degrade_hard = float(degrade_hard)
        self.seed = (_env_int("REPRO_SERVE_SEED", 0)
                     if seed is None else int(seed))
        if self.max_batch < 1 or self.max_pending < 1:
            raise ValueError("max_batch and max_pending must be >= 1")
        if self.pipeline < 0:
            raise ValueError("pipeline must be >= 0")
        if self.retries < 0:
            raise ValueError("retries must be >= 0")
        if self.breaker_n < 1:
            raise ValueError("breaker_n must be >= 1")
        if self.degrade_hard < 1.0:
            raise ValueError("degrade_hard must be >= 1.0")


class Rejected(RuntimeError):
    """Admission refused past the high-water mark; retry after
    ``retry_after_s`` (an estimate from recent drain throughput)."""

    def __init__(self, pending: int, limit: int, retry_after_s: float):
        super().__init__(
            f"admission queue full ({pending} pending query rows >= "
            f"high-water {limit}); retry after ~{retry_after_s * 1e3:.1f}ms")
        self.retry_after_s = retry_after_s


class ServeFuture:
    """Result handle resolved when the request's batch drains.

    Resolution is **idempotent and single-shot**: the first
    ``set_result``/``set_exception`` wins and later ones are ignored, so
    a crash-containment path can never clobber an already-resolved
    future. ``cancel()`` lets a caller that gave up (e.g. after a
    ``result(timeout)`` timeout) withdraw the request: a cancelled
    request is dropped at bucket drain WITHOUT being launched (counted
    as ``serve.expired``), instead of leaking staged device work.

    ``quality`` carries the :class:`~repro.reliability.ResultQuality`
    flags of a successful resolution (None until resolved / on error);
    ``trace_id`` the request-scoped trace context assigned at admission
    (``obs.timeline(fut.trace_id)`` is the request's span timeline).
    """

    __slots__ = ("_event", "_result", "_exc", "_cancelled", "_lock",
                 "request_id", "quality", "trace_id")

    def __init__(self, request_id: int, trace_id: str = ""):
        self.request_id = request_id
        self.trace_id = trace_id
        self._event = threading.Event()
        self._result: SearchResult | None = None
        self._exc: BaseException | None = None
        self._cancelled = False
        self._lock = threading.Lock()
        self.quality: ResultQuality | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def cancelled(self) -> bool:
        return self._cancelled

    def cancel(self) -> bool:
        """Withdraw the request if it has not resolved yet; returns True
        when the cancellation won (the drain will drop it unlaunched)."""
        with self._lock:
            if self._event.is_set():
                return False
            self._cancelled = True
            self._exc = Cancelled(self.request_id)
            self._event.set()
            return True

    def set_result(self, result: SearchResult,
                   quality: ResultQuality | None = None) -> bool:
        """First resolution wins; returns whether this call resolved the
        future (so attribution — SLO, resolve spans — counts each
        request exactly once)."""
        with self._lock:
            if self._event.is_set():
                return False
            self._result = result
            self.quality = quality
            self._event.set()
            return True

    def set_exception(self, exc: BaseException) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._exc = exc
            self._event.set()
            return True

    def exception(self) -> BaseException | None:
        return self._exc if self._event.is_set() else None

    def result(self, timeout: float | None = None) -> SearchResult:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not drained within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result


class _InFlight:
    """One dispatched, not-yet-synced batch riding the drain pipeline.

    Carries its bucket ``key``/``requests`` and dispatch ``attempt`` so
    a transient failure surfacing at sync time can be re-dispatched
    under the same bounded retry budget as a dispatch-time failure.
    """

    __slots__ = ("key", "staged", "result", "t_dispatch", "compiled",
                 "attempt")

    def __init__(self, key, staged, result, t_dispatch, compiled,
                 attempt=0):
        self.key = key
        self.staged = staged
        self.result = result
        self.t_dispatch = t_dispatch
        self.compiled = compiled
        self.attempt = attempt


class NeighborService:
    """The multi-tenant serving frontend over a :class:`SceneRegistry`.

    >>> svc = NeighborService()
    >>> svc.register_scene("city", points)
    >>> fut = svc.submit("city", queries, SearchParams(radius=0.1, k=8))
    >>> svc.drain()
    >>> res = fut.result()
    """

    def __init__(self, opts: ServeOpts | None = None,
                 registry: SceneRegistry | None = None):
        self.opts = opts if opts is not None else ServeOpts()
        # NOT `registry or ...`: an empty registry is falsy (__len__ == 0)
        # but still the caller's shared instance
        self.registry = (registry if registry is not None
                         else SceneRegistry(capacity=self.opts.scenes))
        self._batcher = MicroBatcher()
        self._lock = threading.RLock()
        self._seq = 0
        self._metrics = obs.metric_set("serve")
        self._batch_s = collections.deque(maxlen=32)   # recent drain times
        self._thread: threading.Thread | None = None
        self._stop_event = threading.Event()
        # reliability state (DESIGN.md section 11): one breaker per scene,
        # the repo-shared straggler detector over per-drain durations, and
        # a seeded jitter stream for the retry backoff
        self._breakers: dict = {}
        self._straggler = StragglerMonitor()
        self._jitter_rng = np.random.default_rng(self.opts.seed)

    # -- scene management ---------------------------------------------------

    def register_scene(self, scene_id, points, *, spec=None,
                       warm: tuple[SearchParams, int] | None = None):
        """Admit a static scene. ``warm=(params, nq)`` optionally builds
        the signature variant and compiles its ``nq``-bucket serve program
        up front, so the first drained batch pays no compile."""
        rec = self.registry.add_scene(scene_id, points, spec=spec)
        if warm is not None:
            params, nq = warm
            rec.variant(params).warm(nq)
        return rec

    def register_session(self, scene_id, session):
        """Admit a live ``SimulationSession`` as a dynamic scene (queries
        drain against its current frame)."""
        return self.registry.add_session(scene_id, session)

    # -- admission ----------------------------------------------------------

    def _retry_after(self) -> float:
        mean_batch = (sum(self._batch_s) / len(self._batch_s)
                      if self._batch_s else None)
        return self._batcher._retry_after(mean_batch, self.opts.max_batch,
                                          max(self.opts.retry_floor_s,
                                              self.opts.max_wait_s))

    def _breaker(self, scene_id) -> CircuitBreaker:
        br = self._breakers.get(scene_id)
        if br is None:
            br = self._breakers[scene_id] = CircuitBreaker(
                threshold=self.opts.breaker_n,
                cooldown_s=self.opts.breaker_cooldown_s)
        return br

    def submit(self, scene_id, queries, params: SearchParams,
               opts: SearchOpts = SearchOpts(), *,
               now: float | None = None,
               deadline_s: float | None = None) -> ServeFuture:
        """Admit one request; returns its future (resolved at drain time).

        Raises ``KeyError`` for a non-resident scene, ``QueryError`` for
        unservable inputs (NaN/inf/sentinel rows — rejected BEFORE they
        can reach a concatenated launch), ``CircuitOpen`` while the
        scene's breaker is open, and :class:`Rejected` past the
        ``max_pending`` high-water mark (unless ``ServeOpts.degrade``
        admits it at a reduced ladder level instead). ``now`` overrides
        the admission timestamp (simulated-clock trace drivers);
        ``deadline_s`` the per-request server-side deadline (default
        ``ServeOpts.deadline_s``; 0/None = none).

        Every call is traced: the request gets a process-unique
        ``trace_id`` (on the returned future), the admission is recorded
        as an ``admit`` span carrying it, and refused admissions are
        attributed to the tenant's SLO ledger (``rejected`` /
        ``circuit_open``; ``QueryError`` counts as ``error``, and —
        being a reliability failure path — triggers a flight-recorder
        dump when ``REPRO_FLIGHT`` is on).
        """
        trace_id = f"req-{next(_REQ_IDS):06d}"
        with obs.span("admit", trace=trace_id,
                      tenant=str(scene_id)) as sp:
            try:
                return self._admit(scene_id, queries, params, opts,
                                   now=now, deadline_s=deadline_s,
                                   trace_id=trace_id, sp=sp)
            except QueryError:
                sp.set(outcome="error")
                slo.record(scene_id, "error")
                flightrec.note("query_error", scene=str(scene_id),
                               trace=trace_id)
                flightrec.dump(f"query_error:{scene_id}")
                raise
            except Rejected:
                sp.set(outcome="rejected")
                slo.record(scene_id, "rejected")
                raise
            except CircuitOpen:
                sp.set(outcome="circuit_open")
                slo.record(scene_id, "circuit_open")
                raise

    def _admit(self, scene_id, queries, params: SearchParams,
               opts: SearchOpts, *, now, deadline_s, trace_id,
               sp) -> ServeFuture:
        q = np.asarray(queries, np.float32)
        if q.ndim != 2 or q.shape[1] != 3:
            raise ValueError(f"queries must be [nq, 3], got {q.shape}")
        # fault-injection seam: a scheduled poison corrupts the admitted
        # rows (a byzantine client) — validation below must catch it
        q = faults.maybe_poison(q, scene=scene_id)
        if self.opts.validate:
            try:
                api.validate_queries(q)
            except QueryError:
                self._metrics.count("query_errors")
                raise
        with self._lock:
            if scene_id not in self.registry:
                raise KeyError(f"scene {scene_id!r} is not resident — "
                               "register_scene first")
            t_real = time.monotonic()
            t_sched = t_real if now is None else float(now)
            br = self._breakers.get(scene_id)
            if br is not None and not br.submit_allowed(t_sched):
                self._metrics.count("circuit_open")
                raise CircuitOpen(scene_id, max(br.retry_after(t_sched),
                                                self.opts.retry_floor_s))
            degraded = False
            pending = self._batcher.pending_queries
            if pending + q.shape[0] > self.opts.max_pending:
                hard = int(self.opts.max_pending * self.opts.degrade_hard)
                if self.opts.degrade and pending + q.shape[0] <= hard:
                    # overload mode: serve at the reduced ladder level,
                    # flagged degraded, instead of rejecting
                    degraded = True
                    opts = dataclasses.replace(
                        opts, w_ladder=self.opts.degrade_ladder)
                    self._metrics.count("degraded_admissions")
                else:
                    self._metrics.count("rejected")
                    raise Rejected(pending, self.opts.max_pending,
                                   self._retry_after())
            ddl = self.opts.deadline_s if deadline_s is None \
                else float(deadline_s)
            self._seq += 1
            fut = ServeFuture(self._seq, trace_id)
            req = Request(seq=self._seq, scene_id=scene_id, params=params,
                          opts=opts, queries=q, future=fut,
                          t_submit=t_sched, t_real=t_real,
                          deadline=(t_sched + ddl if ddl else None),
                          degraded=degraded, trace_id=trace_id)
            sp.set(seq=self._seq, nq=q.shape[0], degraded=degraded)
            with obs.span("enqueue", trace=trace_id, nq=q.shape[0]):
                self._batcher.add(req)
            self._metrics.count("requests")
            self._metrics.count("query_rows", q.shape[0])
            self._gauge_depth()
        return fut

    def _gauge_depth(self) -> None:
        nreq, nq = self._batcher.queue_depth()
        self._metrics.gauge("queue_depth", nreq)
        self._metrics.gauge("queue_queries", nq)

    # -- drain --------------------------------------------------------------

    def _drop_dead(self, requests, now: float) -> list:
        """Filter a drained bucket down to launchable requests: expired
        deadlines fail with ``DeadlineExceeded`` and cancelled/already-
        resolved futures are dropped — all BEFORE any staging or launch,
        counted as ``serve.expired``."""
        live = []
        for r in requests:
            if r.future.done():                  # caller-cancelled
                self._metrics.count("cancelled")
            elif r.expired(now):
                if r.future.set_exception(
                        DeadlineExceeded(r.seq, r.deadline, now)):
                    self._metrics.count("expired")
                    self._resolve_span(r, "expired")
                    slo.record(r.scene_id, "expired")
            else:
                live.append(r)
        return live

    def _resolve_span(self, req, outcome: str, attempt: int = 0) -> None:
        """Record the request's terminal ``resolve`` span: its duration
        is the request's end-to-end latency, so on the timeline it
        stretches back to (approximately) the admission — the covering
        interval the per-request reconstruction leans on."""
        obs.record_span("resolve", max(0.0, time.monotonic() - req.t_real),
                        trace=req.trace_id, tenant=str(req.scene_id),
                        seq=req.seq, outcome=outcome, attempt=attempt,
                        degraded=req.degraded)

    def _fail_requests(self, requests, exc: BaseException,
                       attempt: int = 0) -> None:
        outcome = ("circuit_open" if isinstance(exc, CircuitOpen)
                   else "expired" if isinstance(exc, DeadlineExceeded)
                   else "error")
        for r in requests:
            if r.future.set_exception(exc):
                self._resolve_span(r, outcome, attempt)
                slo.record(r.scene_id, outcome)

    def _backoff(self, attempt: int) -> None:
        base = self.opts.backoff_s * (2.0 ** attempt)
        time.sleep(min(base * (0.5 + float(self._jitter_rng.random())),
                       0.25))

    def _dispatch(self, key, requests, attempt: int = 0) -> _InFlight:
        """Stage (host concat/pad/upload) and asynchronously dispatch one
        batch through the scene variant's compiled serve program."""
        scene_id, params, sopts = key
        tids = [r.trace_id for r in requests]
        variant = self.registry.resolve(scene_id, params, sopts)
        # fault-injection seam: a scheduled launch fault fails the batch
        # before any device work (retried by _run_batch)
        faults.maybe_fail("launch", scene=scene_id)
        with obs.span("stage", trace_ids=tids, scene=str(scene_id)):
            staged = stage_batch(key, requests,
                                 variant.pad_to_bucket(
                                     sum(r.nq for r in requests)))
        cache0 = variant.compiled_programs()
        t0 = time.perf_counter()
        with obs.span("launch", trace_ids=tids, scene=str(scene_id),
                      nq=staged.nq, pad_n=staged.pad_n, attempt=attempt):
            result = variant.fn(variant.index, staged.queries)
        compiled = variant.compiled_programs() > cache0
        if compiled:
            variant.warmed.add(staged.pad_n)
            obs.record_span("compile", time.perf_counter() - t0,
                            trace_ids=tids)
        return _InFlight(key, staged, result, t0, compiled, attempt)

    def _run_batch(self, key, requests, now: float) -> _InFlight | None:
        """Dispatch one batch with the bounded transient-retry policy.

        Returns the in-flight record, or None when the batch failed
        permanently — in which case its futures are already failed and
        the scene's breaker has recorded the failure.
        """
        scene_id = key[0]
        attempt = 0
        while True:
            try:
                return self._dispatch(key, requests, attempt)
            except KeyError as exc:
                # scene evicted between admission and drain: fail the
                # batch's futures, keep serving (not a scene *fault* —
                # the breaker does not count residency churn)
                self._fail_requests(
                    requests, KeyError(f"scene {key[0]!r} evicted before "
                                       f"drain: {exc}"))
                self._metrics.count("failed_batches")
                return None
            except Exception as exc:
                if is_transient(exc) and attempt < self.opts.retries:
                    attempt += 1
                    self._metrics.count("retries")
                    flightrec.note("retry", scene=str(scene_id),
                                   attempt=attempt, error=str(exc))
                    self._backoff(attempt - 1)
                    continue
                self._fail_requests(requests, exc, attempt)
                self._metrics.count("failed_batches")
                self._metrics.count("launch_failures")
                flightrec.note("batch_failed", scene=str(scene_id),
                               error=str(exc), attempt=attempt,
                               seqs=[r.seq for r in requests])
                if self._breaker(scene_id).record_failure(now):
                    self._metrics.count("breaker_trips")
                    self._trip_breaker(scene_id)
                return None

    def _trip_breaker(self, scene_id) -> None:
        """A scene's circuit just opened — the canonical flight-recorder
        moment: note the transition and dump the post-mortem (a no-op
        unless ``REPRO_FLIGHT`` is on)."""
        flightrec.note("breaker_trip", scene=str(scene_id),
                       state=self.breaker_state(scene_id))
        flightrec.dump(f"breaker_open:{scene_id}")

    def _finish(self, flight: _InFlight, now_fn=time.monotonic) -> None:
        """The drained batch's ONE blocking host sync, then future
        resolution (device-sliced views — no further transfer)."""
        res = flight.result
        tids = [r.trace_id for r in flight.staged.requests]
        faults.maybe_delay(scene=flight.key[0])   # injected straggler
        with obs.span("sync", trace_ids=tids, scene=str(flight.key[0])):
            jax.block_until_ready((res.indices, res.distances2, res.counts))
        self._metrics.count("host_syncs")
        self._metrics.count("batches")
        dt = time.perf_counter() - flight.t_dispatch
        self._batch_s.append(dt)
        self._metrics.observe("batch_s", dt)
        # per-drain straggler detection: the repo-shared EMA monitor
        # (train.fault_tolerance) flags drains stalling >> steady state
        if self._straggler.observe(dt):
            self._metrics.count("stragglers")
        if self._straggler.ema is not None:
            self._metrics.gauge("batch_ema_s", self._straggler.ema)
        staged = flight.staged
        self._metrics.observe("batch_queries", staged.nq)
        self._metrics.observe("batch_requests", len(staged.requests))
        self._metrics.observe("batch_occupancy", staged.nq / staged.pad_n)
        scene_id, params, sopts = flight.key
        try:
            overflow, oob = self.registry.resolve(
                scene_id, params, sopts).quality_counters()
        except KeyError:               # evicted mid-flight; results stand
            overflow, oob = 0, 0
        now = now_fn()
        with obs.span("split", trace_ids=tids,
                      requests=len(staged.requests)):
            parts = split_result(staged, res)
        occupancy = staged.nq / staged.pad_n
        for req, res_i in zip(staged.requests, parts):
            quality = ResultQuality.from_counters(
                overflow=overflow, oob=oob, reduced_ladder=req.degraded)
            if quality.degraded:
                self._metrics.count("degraded_responses")
            if req.future.set_result(res_i, quality):
                outcome = "degraded" if req.degraded else "ok"
                self._resolve_span(req, outcome, flight.attempt)
                slo.record(req.scene_id, outcome,
                           max(0.0, now - req.t_real),
                           occupancy=occupancy)
            self._metrics.observe("request_s", max(0.0, now - req.t_real))
        self._metrics.count("resolved", len(staged.requests))
        flightrec.note("drain", scene=str(scene_id), nq=staged.nq,
                       pad_n=staged.pad_n, requests=len(staged.requests),
                       batch_s=dt, compiled=flight.compiled,
                       attempt=flight.attempt)

    def _finish_safe(self, flight: _InFlight, now: float) -> None:
        """Sync one in-flight batch, converting failures surfacing at
        sync time into the same bounded-retry / fail-futures / breaker
        policy as dispatch-time failures — a batch can never leave its
        futures unresolved."""
        scene_id = flight.key[0]
        try:
            self._finish(flight)
        except Exception as exc:
            if is_transient(exc) and flight.attempt < self.opts.retries:
                self._metrics.count("retries")
                flightrec.note("retry", scene=str(scene_id),
                               attempt=flight.attempt + 1, at="sync",
                               error=str(exc))
                self._backoff(flight.attempt)
                retry = self._run_batch(flight.key, flight.staged.requests,
                                        now)
                if retry is not None:
                    retry.attempt = max(retry.attempt, flight.attempt + 1)
                    self._finish_safe(retry, now)
                return
            self._fail_requests(flight.staged.requests, exc, flight.attempt)
            self._metrics.count("failed_batches")
            flightrec.note("batch_failed", scene=str(scene_id), at="sync",
                           error=str(exc), attempt=flight.attempt)
            if self._breaker(scene_id).record_failure(now):
                self._metrics.count("breaker_trips")
                self._trip_breaker(scene_id)
            return
        self._breaker(scene_id).record_success()

    def pump(self, now: float | None = None, *,
             force: bool = False) -> list[BatchReport]:
        """Drain every due bucket once; returns the batch reports in drain
        order (the deterministic record tests and drivers consume).

        The loop is pipelined: up to ``opts.pipeline`` dispatched batches
        stay in flight while the next one is staged on the host, and each
        batch's single blocking sync happens only when it leaves the
        pipeline (or at the end of the pump).

        Crash containment: if anything escapes the drain loop, every
        in-flight/taken request's future is failed with the escaping
        exception before it propagates — a pump crash can never strand a
        future unresolved.
        """
        with self._lock:
            now = time.monotonic() if now is None else float(now)
            reports: list[BatchReport] = []
            inflight: collections.deque = collections.deque()
            current: list = []
            try:
                with obs.span("pump", forced=force):
                    while True:
                        taken = self._batcher.take(
                            now, max_wait=self.opts.max_wait_s,
                            max_batch=self.opts.max_batch, force=force)
                        if taken is None:
                            break
                        key, current = taken
                        requests = self._drop_dead(current, now)
                        if not requests:
                            current = []
                            continue
                        scene_id = key[0]
                        br = self._breaker(scene_id)
                        if not br.allow(now):
                            # breaker open: isolate this scene — fail its
                            # batch fast, keep draining the others
                            self._fail_requests(requests, CircuitOpen(
                                scene_id, max(br.retry_after(now),
                                              self.opts.retry_floor_s)))
                            self._metrics.count("circuit_open",
                                                len(requests))
                            current = []
                            continue
                        with obs.span("drain", scene=str(scene_id),
                                      requests=len(requests),
                                      trace_ids=[r.trace_id
                                                 for r in requests]):
                            flight = self._run_batch(key, requests, now)
                        current = []
                        if flight is None:
                            continue
                        scene_id_k, params, _sopts = key
                        reports.append(BatchReport(
                            scene_id=scene_id_k, params=params,
                            seqs=tuple(r.seq for r in requests),
                            nq=flight.staged.nq, pad_n=flight.staged.pad_n))
                        inflight.append(flight)
                        # dispatch-then-stage: sync the OLDEST in-flight
                        # batch only once the pipeline is over depth, so
                        # the next iteration's staging overlapped this
                        # batch's execution
                        while len(inflight) > self.opts.pipeline:
                            self._finish_safe(inflight.popleft(), now)
                    while inflight:
                        self._finish_safe(inflight.popleft(), now)
            except BaseException as exc:
                # crash containment: no future may hang on a pump crash
                self._fail_requests(current, exc)
                for fl in inflight:
                    self._fail_requests(fl.staged.requests, exc)
                self._metrics.count("pump_crashes")
                flightrec.note("pump_crash", error=str(exc),
                               stranded=len(current) + sum(
                                   len(fl.staged.requests)
                                   for fl in inflight))
                flightrec.dump("pump_crash")
                raise
            finally:
                self._gauge_depth()
            return reports

    def drain(self, now: float | None = None) -> list[BatchReport]:
        """Force-pump until the admission queue is empty. ``now`` pins the
        scheduling clock (simulated-clock drivers must drain on the same
        clock their deadlines were set against)."""
        reports: list[BatchReport] = []
        while True:
            got = self.pump(now, force=True)
            if not got:
                if self._batcher.empty():
                    break
                continue                 # only dead/isolated buckets drained
            reports.extend(got)
        return reports

    # -- background pump ----------------------------------------------------

    def start(self, poll_s: float | None = None) -> None:
        """Run the pump on a daemon thread (real streaming callers). The
        thread wakes every ``poll_s`` (default: half the bucket deadline)
        and drains whatever is due. Crash-contained: an exception escaping
        ``pump()`` (whose own handler already failed the in-flight
        futures) is counted as ``serve.pump_restarts`` and the loop keeps
        pumping instead of dying silently."""
        if self._thread is not None:
            return
        period = poll_s if poll_s is not None else \
            max(self.opts.max_wait_s / 2, 1e-4)
        self._stop_event.clear()

        def loop():
            while not self._stop_event.wait(period):
                try:
                    self.pump()
                except Exception:
                    self._metrics.count("pump_restarts")

        self._thread = threading.Thread(target=loop, name="repro-serve-pump",
                                        daemon=True)
        self._thread.start()

    def stop(self, final_drain: bool = True) -> None:
        if self._thread is None:
            return
        self._stop_event.set()
        self._thread.join()
        self._thread = None
        if final_drain:
            self.drain()

    # -- surface ------------------------------------------------------------

    def queue_depth(self) -> int:
        return self._batcher.pending_requests

    def breaker_state(self, scene_id) -> str:
        """The scene's circuit-breaker state ("closed" when untracked)."""
        br = self._breakers.get(scene_id)
        return br.state if br is not None else "closed"

    def stats(self) -> dict:
        nreq, nq = self._batcher.queue_depth()
        return {
            **self._metrics.counters(),
            "queue_depth": nreq,
            "queue_queries": nq,
            "breakers": {sid: br.state
                         for sid, br in self._breakers.items()},
            "registry": self.registry.stats(),
        }
