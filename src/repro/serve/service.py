"""Multi-tenant streaming neighbor-query service (DESIGN.md section 10).

``NeighborService`` layers the serving contract over the functional core:

* ``submit(scene_id, queries, params)`` admits a request and returns a
  :class:`ServeFuture` resolved at drain time. Admission is bounded: past
  the ``max_pending`` high-water mark the queue **rejects with
  retry-after** (:class:`Rejected`) instead of growing without bound.
* ``pump()`` drains every *due* signature bucket (see ``batcher``) as one
  concatenated launch through the scene's variant-private compiled
  ``api.query`` program — ONE blocking host sync per drained batch — with
  the next batch staged and dispatched while the previous one executes
  (``pipeline`` in-flight batches; the dispatch-then-stage overlap).
* ``drain()`` pumps with the deadline forced until the queue is empty.
* ``start()/stop()`` run the pump on a background thread for real
  streaming callers; the synchronous surface stays fully deterministic for
  tests and the trace driver.

Every stage feeds the unified telemetry layer (``repro.obs``, component
``serve``): queue-depth gauges, batch-occupancy histograms, end-to-end
request latency percentiles, and the host-sync counter the one-sync
contract is asserted against. ``obs.summary()`` over a serving process
reads as the service dashboard.
"""
from __future__ import annotations

import collections
import os
import threading
import time

import jax

from .. import obs
from ..core.types import SearchOpts, SearchParams, SearchResult
from .batcher import BatchReport, MicroBatcher, Request, split_result, \
    stage_batch
from .registry import SceneRegistry


def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def _env_float(name: str, default: float) -> float:
    return float(os.environ.get(name, default))


class ServeOpts:
    """Service knobs (env defaults, DESIGN.md section 4 ``REPRO_SERVE_*``).

    ``max_pending``   admission high-water mark in pending *query rows*;
    ``max_batch``     max concatenated query rows per drained launch;
    ``max_wait_s``    bucket deadline — a request waits at most this long
                      before its bucket is due even if nearly empty
                      (``REPRO_SERVE_MAX_WAIT_MS`` is in milliseconds);
    ``pipeline``      in-flight launches the drain loop keeps before
                      syncing the oldest (0 = sync immediately after each
                      dispatch, i.e. no overlap);
    ``scenes``        registry capacity (resident scenes, LRU-evicted).
    """

    __slots__ = ("max_pending", "max_batch", "max_wait_s", "pipeline",
                 "scenes")

    def __init__(self, max_pending: int | None = None,
                 max_batch: int | None = None,
                 max_wait_s: float | None = None,
                 pipeline: int | None = None,
                 scenes: int | None = None):
        self.max_pending = (_env_int("REPRO_SERVE_MAX_PENDING", 65536)
                            if max_pending is None else int(max_pending))
        self.max_batch = (_env_int("REPRO_SERVE_MAX_BATCH", 4096)
                          if max_batch is None else int(max_batch))
        self.max_wait_s = (
            _env_float("REPRO_SERVE_MAX_WAIT_MS", 2.0) / 1e3
            if max_wait_s is None else float(max_wait_s))
        self.pipeline = (_env_int("REPRO_SERVE_PIPELINE", 1)
                         if pipeline is None else int(pipeline))
        self.scenes = (_env_int("REPRO_SERVE_SCENES", 8)
                       if scenes is None else int(scenes))
        if self.max_batch < 1 or self.max_pending < 1:
            raise ValueError("max_batch and max_pending must be >= 1")
        if self.pipeline < 0:
            raise ValueError("pipeline must be >= 0")


class Rejected(RuntimeError):
    """Admission refused past the high-water mark; retry after
    ``retry_after_s`` (an estimate from recent drain throughput)."""

    def __init__(self, pending: int, limit: int, retry_after_s: float):
        super().__init__(
            f"admission queue full ({pending} pending query rows >= "
            f"high-water {limit}); retry after ~{retry_after_s * 1e3:.1f}ms")
        self.retry_after_s = retry_after_s


class ServeFuture:
    """Result handle resolved when the request's batch drains."""

    __slots__ = ("_event", "_result", "_exc", "request_id")

    def __init__(self, request_id: int):
        self.request_id = request_id
        self._event = threading.Event()
        self._result: SearchResult | None = None
        self._exc: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def set_result(self, result: SearchResult) -> None:
        self._result = result
        self._event.set()

    def set_exception(self, exc: BaseException) -> None:
        self._exc = exc
        self._event.set()

    def exception(self) -> BaseException | None:
        return self._exc if self._event.is_set() else None

    def result(self, timeout: float | None = None) -> SearchResult:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not drained within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result


class _InFlight:
    """One dispatched, not-yet-synced batch riding the drain pipeline."""

    __slots__ = ("staged", "result", "t_dispatch", "compiled")

    def __init__(self, staged, result, t_dispatch, compiled):
        self.staged = staged
        self.result = result
        self.t_dispatch = t_dispatch
        self.compiled = compiled


class NeighborService:
    """The multi-tenant serving frontend over a :class:`SceneRegistry`.

    >>> svc = NeighborService()
    >>> svc.register_scene("city", points)
    >>> fut = svc.submit("city", queries, SearchParams(radius=0.1, k=8))
    >>> svc.drain()
    >>> res = fut.result()
    """

    def __init__(self, opts: ServeOpts | None = None,
                 registry: SceneRegistry | None = None):
        self.opts = opts if opts is not None else ServeOpts()
        # NOT `registry or ...`: an empty registry is falsy (__len__ == 0)
        # but still the caller's shared instance
        self.registry = (registry if registry is not None
                         else SceneRegistry(capacity=self.opts.scenes))
        self._batcher = MicroBatcher()
        self._lock = threading.RLock()
        self._seq = 0
        self._metrics = obs.metric_set("serve")
        self._batch_s = collections.deque(maxlen=32)   # recent drain times
        self._thread: threading.Thread | None = None
        self._stop_event = threading.Event()

    # -- scene management ---------------------------------------------------

    def register_scene(self, scene_id, points, *, spec=None,
                       warm: tuple[SearchParams, int] | None = None):
        """Admit a static scene. ``warm=(params, nq)`` optionally builds
        the signature variant and compiles its ``nq``-bucket serve program
        up front, so the first drained batch pays no compile."""
        rec = self.registry.add_scene(scene_id, points, spec=spec)
        if warm is not None:
            params, nq = warm
            rec.variant(params).warm(nq)
        return rec

    def register_session(self, scene_id, session):
        """Admit a live ``SimulationSession`` as a dynamic scene (queries
        drain against its current frame)."""
        return self.registry.add_session(scene_id, session)

    # -- admission ----------------------------------------------------------

    def _retry_after(self) -> float:
        mean_batch = (sum(self._batch_s) / len(self._batch_s)
                      if self._batch_s else self.opts.max_wait_s)
        backlog = self._batcher.pending_queries / max(self.opts.max_batch, 1)
        return max(self.opts.max_wait_s, mean_batch * max(backlog, 1.0))

    def submit(self, scene_id, queries, params: SearchParams,
               opts: SearchOpts = SearchOpts(), *,
               now: float | None = None) -> ServeFuture:
        """Admit one request; returns its future (resolved at drain time).

        Raises ``KeyError`` for a non-resident scene and :class:`Rejected`
        past the ``max_pending`` high-water mark. ``now`` overrides the
        admission timestamp (simulated-clock trace drivers).
        """
        import numpy as np

        q = np.asarray(queries, np.float32)
        if q.ndim != 2 or q.shape[1] != 3:
            raise ValueError(f"queries must be [nq, 3], got {q.shape}")
        with self._lock:
            if scene_id not in self.registry:
                raise KeyError(f"scene {scene_id!r} is not resident — "
                               "register_scene first")
            pending = self._batcher.pending_queries
            if pending + q.shape[0] > self.opts.max_pending:
                self._metrics.count("rejected")
                raise Rejected(pending, self.opts.max_pending,
                               self._retry_after())
            self._seq += 1
            fut = ServeFuture(self._seq)
            t_real = time.monotonic()
            req = Request(seq=self._seq, scene_id=scene_id, params=params,
                          opts=opts, queries=q, future=fut,
                          t_submit=t_real if now is None else float(now),
                          t_real=t_real)
            self._batcher.add(req)
            self._metrics.count("requests")
            self._metrics.count("query_rows", q.shape[0])
            self._gauge_depth()
        return fut

    def _gauge_depth(self) -> None:
        nreq, nq = self._batcher.queue_depth()
        self._metrics.gauge("queue_depth", nreq)
        self._metrics.gauge("queue_queries", nq)

    # -- drain --------------------------------------------------------------

    def _dispatch(self, key, requests) -> _InFlight:
        """Stage (host concat/pad/upload) and asynchronously dispatch one
        batch through the scene variant's compiled serve program."""
        scene_id, params, sopts = key
        variant = self.registry.resolve(scene_id, params, sopts)
        staged = stage_batch(key, requests,
                             variant.pad_to_bucket(
                                 sum(r.nq for r in requests)))
        cache0 = variant.compiled_programs()
        t0 = time.perf_counter()
        result = variant.fn(variant.index, staged.queries)
        compiled = variant.compiled_programs() > cache0
        if compiled:
            variant.warmed.add(staged.pad_n)
            obs.record_span("compile", time.perf_counter() - t0)
        return _InFlight(staged, result, t0, compiled)

    def _finish(self, flight: _InFlight, now_fn=time.monotonic) -> None:
        """The drained batch's ONE blocking host sync, then future
        resolution (device-sliced views — no further transfer)."""
        res = flight.result
        with obs.span("sync"):
            jax.block_until_ready((res.indices, res.distances2, res.counts))
        self._metrics.count("host_syncs")
        self._metrics.count("batches")
        dt = time.perf_counter() - flight.t_dispatch
        self._batch_s.append(dt)
        self._metrics.observe("batch_s", dt)
        staged = flight.staged
        self._metrics.observe("batch_queries", staged.nq)
        self._metrics.observe("batch_requests", len(staged.requests))
        self._metrics.observe("batch_occupancy", staged.nq / staged.pad_n)
        now = now_fn()
        for req, res_i in zip(staged.requests, split_result(staged, res)):
            req.future.set_result(res_i)
            self._metrics.observe("request_s", max(0.0, now - req.t_real))
        self._metrics.count("resolved", len(staged.requests))

    def pump(self, now: float | None = None, *,
             force: bool = False) -> list[BatchReport]:
        """Drain every due bucket once; returns the batch reports in drain
        order (the deterministic record tests and drivers consume).

        The loop is pipelined: up to ``opts.pipeline`` dispatched batches
        stay in flight while the next one is staged on the host, and each
        batch's single blocking sync happens only when it leaves the
        pipeline (or at the end of the pump).
        """
        with self._lock:
            now = time.monotonic() if now is None else float(now)
            reports: list[BatchReport] = []
            inflight: collections.deque = collections.deque()
            with obs.span("pump", forced=force):
                while True:
                    taken = self._batcher.take(
                        now, max_wait=self.opts.max_wait_s,
                        max_batch=self.opts.max_batch, force=force)
                    if taken is None:
                        break
                    key, requests = taken
                    with obs.span("launch", scene=str(key[0]),
                                  requests=len(requests)):
                        try:
                            flight = self._dispatch(key, requests)
                        except KeyError as exc:
                            # scene evicted between admission and drain:
                            # fail the batch's futures, keep serving
                            for r in requests:
                                r.future.set_exception(
                                    KeyError(f"scene {key[0]!r} evicted "
                                             f"before drain: {exc}"))
                            self._metrics.count("failed_batches")
                            continue
                    scene_id, params, _sopts = key
                    reports.append(BatchReport(
                        scene_id=scene_id, params=params,
                        seqs=tuple(r.seq for r in requests),
                        nq=flight.staged.nq, pad_n=flight.staged.pad_n))
                    inflight.append(flight)
                    # dispatch-then-stage: sync the OLDEST in-flight batch
                    # only once the pipeline is over depth, so the next
                    # iteration's staging overlapped this batch's execution
                    while len(inflight) > self.opts.pipeline:
                        self._finish(inflight.popleft())
                while inflight:
                    self._finish(inflight.popleft())
            self._gauge_depth()
            return reports

    def drain(self) -> list[BatchReport]:
        """Force-pump until the admission queue is empty."""
        reports: list[BatchReport] = []
        while True:
            got = self.pump(force=True)
            if not got:
                break
            reports.extend(got)
        return reports

    # -- background pump ----------------------------------------------------

    def start(self, poll_s: float | None = None) -> None:
        """Run the pump on a daemon thread (real streaming callers). The
        thread wakes every ``poll_s`` (default: half the bucket deadline)
        and drains whatever is due."""
        if self._thread is not None:
            return
        period = poll_s if poll_s is not None else \
            max(self.opts.max_wait_s / 2, 1e-4)
        self._stop_event.clear()

        def loop():
            while not self._stop_event.wait(period):
                self.pump()

        self._thread = threading.Thread(target=loop, name="repro-serve-pump",
                                        daemon=True)
        self._thread.start()

    def stop(self, final_drain: bool = True) -> None:
        if self._thread is None:
            return
        self._stop_event.set()
        self._thread.join()
        self._thread = None
        if final_drain:
            self.drain()

    # -- surface ------------------------------------------------------------

    def queue_depth(self) -> int:
        return self._batcher.pending_requests

    def stats(self) -> dict:
        nreq, nq = self._batcher.queue_depth()
        return {
            **self._metrics.counters(),
            "queue_depth": nreq,
            "queue_queries": nq,
            "registry": self.registry.stats(),
        }
