"""Scene registry: LRU of device-resident scenes keyed by scene id
(DESIGN.md section 10).

A serving process holds many tenants' scenes but bounded device memory, so
residency is explicit: a :class:`SceneRegistry` keeps at most ``capacity``
scenes resident, each a :class:`SceneRecord` owning the uploaded points and
one :class:`SceneVariant` per search signature ``(SearchParams,
SearchOpts)`` — the unit the micro-batcher buckets requests by. A variant
owns a built ``NeighborSearch`` (functional ``NeighborIndex`` + the
host-planned ``QueryExecutor`` with its plan/compile caches) plus a
*private* jitted ``api.query`` wrapper, so evicting the scene releases
every compiled serve program along with the executor caches
(``executor.invalidate()``) instead of pinning them in a process-global
jit cache forever. Eviction fires registered callbacks so the service can
fail or re-route in-flight requests for the evicted tenant.

Live :class:`~repro.core.SimulationSession` scenes register too
(``add_session``): their variant serves queries against the session's
*current* index leaves — same aux, so stepping the session never retraces
the serve program.
"""
from __future__ import annotations

import collections
import threading

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core import api
from ..core.search import NeighborSearch, _pad_bucket
from ..core.types import SearchOpts, SearchParams


def _fresh_query_fn():
    """A jitted ``api.query`` with its OWN jit cache (a distinct closure
    per call), so dropping the variant releases its compiled programs."""

    def _serve_query(index, queries):
        return api.query(index, queries)

    return jax.jit(_serve_query)


class SceneVariant:
    """One scene under one search signature: the compiled serving unit.

    ``index`` is the functional pytree the drained launches run against;
    ``fn`` the variant-private jitted ``api.query``; ``searcher`` the eager
    host-planned surface over the same leaves (its executor caches are the
    per-scene cache handles the registry invalidates on evict).
    """

    __slots__ = ("params", "opts", "searcher", "session", "fn", "warmed",
                 "_quality")

    def __init__(self, params: SearchParams, opts: SearchOpts, *,
                 searcher: NeighborSearch | None = None, session=None):
        self.params = params
        self.opts = opts
        self.searcher = searcher
        self.session = session
        self.fn = _fresh_query_fn()
        self.warmed: set[int] = set()
        self._quality: tuple[int, int] | None = None

    @property
    def index(self) -> api.NeighborIndex:
        if self.session is not None:
            return self.session.index
        return self.searcher.index

    def pad_to_bucket(self, n: int) -> int:
        """Padded launch size for ``n`` concatenated queries (power-of-two
        multiple of the query tile — the executor's recompile-bounding
        bucket discipline)."""
        return _pad_bucket(n, self.opts.query_tile)

    def warm(self, nq: int) -> int:
        """Compile the serve program for the ``nq``-query bucket (one dummy
        launch); returns the padded bucket size. Idempotent per bucket."""
        pad_n = self.pad_to_bucket(nq)
        if pad_n not in self.warmed:
            dummy = jnp.zeros((pad_n, 3), jnp.float32)
            jax.block_until_ready(self.fn(self.index, dummy))
            self.warmed.add(pad_n)
        return pad_n

    def quality_counters(self) -> tuple[int, int]:
        """``(overflow, oob)`` device quality counters for responses served
        off this variant (DESIGN.md section 11). A static scene's grid is
        frozen after build, so its overflow scalar is fetched ONCE and
        cached — no extra per-drain host sync; a session-backed scene reads
        the host-side counters the session's packed telemetry already
        published for the current frame (no device fetch at all)."""
        if self.session is not None:
            rep = self.session.report
            return int(rep.overflow), int(rep.oob)
        if self._quality is None:
            self._quality = (
                int(jax.device_get(self.searcher.index.grid.overflow)), 0)
        return self._quality

    def compiled_programs(self) -> int:
        """Entries in the variant-private jit cache (tests assert re-warm
        after eviction/readmission through this)."""
        try:
            return int(self.fn._cache_size())
        except AttributeError:          # pragma: no cover - older jax
            return len(self.warmed)

    def release(self) -> None:
        """Drop compiled state: executor plan/compile caches and the
        variant-private jitted programs."""
        if self.searcher is not None:
            self.searcher.executor.invalidate()
        self.fn = None
        self.warmed.clear()


class SceneRecord:
    """One resident scene: the uploaded points plus its signature variants."""

    __slots__ = ("scene_id", "points", "spec", "session", "_variants")

    def __init__(self, scene_id, points=None, *, spec=None, session=None):
        self.scene_id = scene_id
        self.session = session
        self.spec = spec
        if session is not None:
            self.points = None
        else:
            self.points = np.asarray(points, np.float32)
        self._variants: dict = {}

    def variant(self, params: SearchParams,
                opts: SearchOpts = SearchOpts()) -> SceneVariant:
        """Get-or-build the scene's variant for one search signature."""
        key = (params, opts)
        v = self._variants.get(key)
        if v is not None:
            return v
        if self.session is not None:
            if params != self.session.params:
                raise ValueError(
                    f"scene {self.scene_id!r} is session-backed with params "
                    f"{self.session.params}; cannot serve {params}")
            v = SceneVariant(params, opts, session=self.session)
        else:
            v = SceneVariant(params, opts, searcher=NeighborSearch(
                self.points, params, opts, spec=self.spec))
        self._variants[key] = v
        return v

    def variants(self):
        return list(self._variants.values())

    def release(self) -> None:
        for v in self._variants.values():
            v.release()
        self._variants.clear()


class SceneRegistry:
    """LRU of resident :class:`SceneRecord`\\ s, explicit capacity.

    ``get``/``resolve`` touch the entry (most-recently-used); ``add_*``
    past capacity evicts the least-recently-used scene — releasing its
    executor caches and compiled serve programs and firing every
    ``on_evict`` callback with ``(scene_id, record)``.
    """

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError("registry capacity must be >= 1")
        self.capacity = int(capacity)
        self._records: collections.OrderedDict = collections.OrderedDict()
        self._callbacks: list = []
        self._lock = threading.RLock()
        self._metrics = obs.metric_set("serve_registry")

    # -- membership ---------------------------------------------------------

    def on_evict(self, callback) -> None:
        """Register ``callback(scene_id, record)`` to fire on eviction."""
        self._callbacks.append(callback)

    def add_scene(self, scene_id, points, *, spec=None) -> SceneRecord:
        """Admit (or replace) a static scene; evicts LRU past capacity."""
        return self._admit(SceneRecord(scene_id, points, spec=spec))

    def add_session(self, scene_id, session) -> SceneRecord:
        """Admit a live ``SimulationSession`` as a dynamic scene."""
        return self._admit(SceneRecord(scene_id, session=session))

    def _admit(self, rec: SceneRecord) -> SceneRecord:
        with self._lock:
            old = self._records.pop(rec.scene_id, None)
            if old is not None:
                old.release()
            self._records[rec.scene_id] = rec
            self._metrics.count("admissions")
            while len(self._records) > self.capacity:
                lru_id = next(iter(self._records))
                self._evict_locked(lru_id)
            self._metrics.gauge("resident_scenes", len(self._records))
        return rec

    def evict(self, scene_id) -> None:
        with self._lock:
            self._evict_locked(scene_id)
            self._metrics.gauge("resident_scenes", len(self._records))

    def _evict_locked(self, scene_id) -> None:
        rec = self._records.pop(scene_id)
        rec.release()
        self._metrics.count("evictions")
        for cb in self._callbacks:
            cb(scene_id, rec)

    def clear(self) -> None:
        with self._lock:
            for scene_id in list(self._records):
                self._evict_locked(scene_id)
            self._metrics.gauge("resident_scenes", 0)

    # -- lookup -------------------------------------------------------------

    def __contains__(self, scene_id) -> bool:
        with self._lock:
            return scene_id in self._records

    def __len__(self) -> int:
        return len(self._records)

    def get(self, scene_id) -> SceneRecord:
        """Fetch + LRU-touch; ``KeyError`` when not resident."""
        with self._lock:
            rec = self._records[scene_id]
            self._records.move_to_end(scene_id)
            return rec

    def resolve(self, scene_id, params: SearchParams,
                opts: SearchOpts = SearchOpts()) -> SceneVariant:
        """``get`` + get-or-build the signature variant (the drain path)."""
        return self.get(scene_id).variant(params, opts)

    def scene_ids(self) -> list:
        with self._lock:
            return list(self._records)

    def stats(self) -> dict:
        with self._lock:
            return {
                **self._metrics.counters(),
                "resident_scenes": len(self._records),
                "capacity": self.capacity,
                "variants": sum(len(r._variants)
                                for r in self._records.values()),
            }
