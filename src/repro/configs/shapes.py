"""The four assigned input shapes (LM-family): seq_len x global_batch.

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len), not ``train_step``. ``long_500k`` requires sub-quadratic
decode state and is only run for archs with ``subquadratic=True``
(DESIGN.md section 4); skipped cells are reported, not silently shrunk.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable(arch_cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-not). The skip rules of the assignment brief."""
    if shape.name == "long_500k" and not arch_cfg.subquadratic:
        return False, ("pure full-attention arch: 524k-token decode is "
                       "O(seq) KV read per token; skipped per brief "
                       "(DESIGN.md section 4)")
    if arch_cfg.enc_dec and shape.seq_len > arch_cfg.max_target_len \
            and shape.kind in ("prefill", "decode"):
        return False, (f"whisper decoder position cap is "
                       f"{arch_cfg.max_target_len}; {shape.seq_len}-token "
                       "serve shapes are architecturally invalid")
    return True, ""
