"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-v01; unverified].

Assigned: 64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000 —
GQA, no-bias.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_head=128,
    d_ff=33792,
    vocab=256000,
    layer_pattern=("attn",),
))
