"""Reduced same-family smoke configs: small layers/width/experts/vocab,
pattern-preserving, runnable on CPU in a forward/train step."""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig, MLAConfig, MoEConfig


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    if cfg.n_kv_heads == cfg.n_heads:
        n_kv = n_heads
    d_head = 16
    d_model = 64
    # keep at least one full pattern period + prefix + tail representation
    period = len(cfg.layer_pattern)
    n_layers = cfg.dense_prefix + 2 * period + (1 if period > 1 else 0)
    moe = None
    if cfg.moe is not None:
        # capacity_factor 8: dropless at smoke scale so the decode-vs-
        # parallel equivalence test is exact (production keeps 1.25, where
        # capacity drops are expected behavior)
        moe = MoEConfig(n_experts=4, top_k=min(cfg.moe.top_k, 2),
                        n_shared=cfg.moe.n_shared,
                        d_expert=32 if cfg.moe.d_expert else None,
                        router_aux_free=cfg.moe.router_aux_free,
                        capacity_factor=8.0)
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(q_rank=32, kv_rank=16, d_nope=16, d_rope=8, d_v=16)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=d_head,
        d_ff=96,
        vocab=256,
        moe=moe,
        mla=mla,
        local_window=8,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_context=16,
        max_target_len=64,
        n_vision_tokens=8 if cfg.n_vision_tokens else 0,
        rwkv_head_dim=16,
    )
