"""Assigned architecture configs. Importing this package registers all 10.

Each ``<arch>.py`` holds the exact published config from the assignment;
``smoke.py`` derives reduced same-family configs for CPU tests; ``shapes.py``
holds the four input shapes.
"""
from . import (deepseek_v3_671b, grok_1_314b, recurrentgemma_2b,
               command_r_plus_104b, qwen1_5_110b, command_r_35b,
               minicpm3_4b, qwen2_vl_7b, whisper_tiny, rwkv6_7b, lm_100m)
from .shapes import SHAPES, ShapeSpec, applicable
from .smoke import smoke_config

ALL_ARCHS = [
    "deepseek-v3-671b", "grok-1-314b", "recurrentgemma-2b",
    "command-r-plus-104b", "qwen1.5-110b", "command-r-35b",
    "minicpm3-4b", "qwen2-vl-7b", "whisper-tiny", "rwkv6-7b",
]
