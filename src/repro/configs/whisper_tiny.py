"""Whisper-tiny [arXiv:2212.04356; unverified].

Assigned: 4L d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865 — enc-dec,
conv frontend (stub). The conv1d+log-mel frontend is STUBBED: input_specs
provides precomputed 1500-frame embeddings. Decoder positions are learned
and capped at 448 (serve shapes beyond that are reported as
architecturally-invalid cells, DESIGN.md section 4).
"""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_head=64,
    d_ff=1536,
    vocab=51865,
    pos="learned",
    layer_pattern=("attn",),
    enc_dec=True,
    n_enc_layers=4,
    enc_context=1500,
    max_target_len=448,
))
