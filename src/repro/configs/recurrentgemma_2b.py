"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf].

Assigned: 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000 —
RG-LRU + local attn, 1:2 (two recurrent layers per local-attention layer;
window 2048). Sub-quadratic: runs long_500k decode.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    vocab=256000,
    layer_pattern=("rglru", "rglru", "local_attn"),
    local_window=2048,
    subquadratic=True,
))
