"""~100M-parameter LLaMA-style model for the end-to-end training example
(examples/train_lm.py; not one of the 10 assigned archs).

12L d=768 12H (GQA kv=4) d_ff=2048 vocab=32000 -> ~110M params.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="lm-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_head=64,
    d_ff=2048,
    vocab=32000,
    layer_pattern=("attn",),
))
