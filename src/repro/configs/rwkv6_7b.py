"""RWKV-6 (Finch) 7B [arXiv:2404.05892; hf].

Assigned: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 —
data-dependent decay. Sub-quadratic (constant-size decode state): runs
long_500k decode.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab=65536,
    pos="none",
    layer_pattern=("rwkv",),
    rwkv_head_dim=64,
    subquadratic=True,
))
