"""DeepSeek-V3 671B [arXiv:2412.19437; hf].

Assigned: 61L d_model=7168 128H (GQA kv=128) d_ff=2048 vocab=129280,
MoE 256e top-8 — MLA, 1 shared + 256 routed top-8, MTP.
d_ff=2048 is the per-expert width (the HF config's moe_intermediate_size);
the real model's 3 dense-prefix layers use 18432 — we keep the assigned
2048 everywhere to match the assignment cell exactly (noted deviation).
MLA dims from the HF config: q_lora_rank 1536, kv_lora_rank 512,
qk_nope/rope 128/64, v_head 128.
"""
from repro.models.config import ArchConfig, MLAConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    d_ff=2048,
    vocab=129280,
    rope_theta=10000.0,
    layer_pattern=("attn",),
    dense_prefix=3,
    moe=MoEConfig(n_experts=256, top_k=8, n_shared=1, d_expert=2048,
                  router_aux_free=True),
    mla=MLAConfig(q_rank=1536, kv_rank=512, d_nope=128, d_rope=64, d_v=128),
    mtp=True,
))
