"""Qwen2-VL-7B [arXiv:2409.12191; hf].

Assigned: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 —
M-RoPE, dynamic resolution. The vision tower is a STUB per the brief:
``input_specs()`` provides precomputed patch embeddings + 3-D M-RoPE
positions; the backbone here is the language decoder.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_head=128,
    d_ff=18944,
    vocab=152064,
    attn_bias=True,
    pos="mrope",
    layer_pattern=("attn",),
    frontend="vision_stub",
    n_vision_tokens=1024,
))
