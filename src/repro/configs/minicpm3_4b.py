"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B; hf].

Assigned: 62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448 — MLA.
MLA dims from the HF config: q_lora_rank 768, kv_lora_rank 256,
qk_nope/rope 64/32, v_head 64.
"""
from repro.models.config import ArchConfig, MLAConfig, register

CONFIG = register(ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_head=64,
    d_ff=6400,
    vocab=73448,
    layer_pattern=("attn",),
    mla=MLAConfig(q_rank=768, kv_rank=256, d_nope=64, d_rope=32, d_v=64),
))
