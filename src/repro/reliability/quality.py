"""Per-response result-quality flags (DESIGN.md section 11).

RT-kNNS Unbound's core observation (PAPERS.md) is that radius-capped
search *silently* drops true neighbors exactly where the device
counters already say so: a grid cell past ``capacity`` truncates its
occupants (``overflow``), and a point binned outside the frozen grid
(``oob``) is invisible to every window. PR 6 made those counters
device-resident and free to read (they ride the packed telemetry sync);
this module attaches them to every served response, so "this answer may
be missing neighbors" is a flag the caller sees instead of a silent
property of the scene.

``degraded`` is also set when the service deliberately served the
request at a reduced ladder level under overload (``ServeOpts.degrade``
— a bounded-window answer instead of a ``Rejected``): the classic
quality-for-availability trade, made explicit per response.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ResultQuality:
    """Quality metadata riding every resolved ``ServeFuture``.

    ``exact``          no known loss source: full ladder, zero scene
                       overflow/oob — the response is bitwise what
                       ``api.query`` returns for this request alone.
    ``degraded``       at least one loss source applied (the union of
                       the flags below).
    ``reduced_ladder`` served at the overload ladder (bounded window):
                       neighbors beyond the capped window are absent.
    ``overflow``       scene-side truncated points (cell capacity); >0
                       means true neighbors may be missing anywhere.
    ``oob``            scene points outside the frozen grid this frame
                       (dynamic scenes mid-respec); >0 means those
                       points are invisible to the search.
    ``reason``         short human tag ("" when exact).
    """

    degraded: bool = False
    reduced_ladder: bool = False
    overflow: int = 0
    oob: int = 0
    reason: str = ""

    @property
    def exact(self) -> bool:
        return not self.degraded

    @classmethod
    def from_counters(cls, *, overflow: int = 0, oob: int = 0,
                      reduced_ladder: bool = False) -> "ResultQuality":
        overflow, oob = int(overflow), int(oob)
        reasons = []
        if reduced_ladder:
            reasons.append("overload ladder cap")
        if overflow > 0:
            reasons.append(f"scene overflow={overflow}")
        if oob > 0:
            reasons.append(f"scene oob={oob}")
        return cls(degraded=bool(reasons), reduced_ladder=reduced_ladder,
                   overflow=overflow, oob=oob, reason="; ".join(reasons))


EXACT = ResultQuality()
