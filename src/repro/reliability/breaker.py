"""Per-scene circuit breaker (DESIGN.md section 11).

The failure mode this isolates: one tenant's scene keeps failing its
launches (a poisoned index, a pathological signature, an injected fault
schedule) and, without a breaker, every drain cycle burns its retry
budget against that scene while other tenants' buckets wait behind it.

Classic three-state machine, driven entirely by the caller's clock (the
serve pump passes its own ``now`` — virtual in trace drivers and tests,
monotonic in production — so breaker behavior is deterministic under a
simulated clock):

* ``CLOSED``    — normal service. ``failures`` counts *consecutive*
                  batch failures; a success resets it; reaching
                  ``threshold`` trips to OPEN.
* ``OPEN``      — fail fast: every ``allow()`` is False (the pump fails
                  that scene's drained buckets with ``CircuitOpen``
                  without launching; ``submit_allowed`` lets the
                  admission path reject before queueing) until
                  ``cooldown_s`` has elapsed.
* ``HALF_OPEN`` — after the cooldown, exactly ONE probe batch is let
                  through. Success closes the breaker (full reset);
                  failure re-opens it with the cooldown doubled (capped
                  at ``cooldown_max_s``), so a persistently-broken scene
                  backs off geometrically instead of probing at a fixed
                  rate.
"""
from __future__ import annotations

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """One scene's breaker; the serve pump owns one per scene id."""

    __slots__ = ("threshold", "cooldown_s", "cooldown_max_s", "state",
                 "failures", "opened_at", "_cooldown", "_probing",
                 "trips", "probes")

    def __init__(self, threshold: int = 3, cooldown_s: float = 0.05,
                 cooldown_max_s: float | None = None):
        if threshold < 1:
            raise ValueError("breaker threshold must be >= 1")
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        # default cap scales with the base so geometric backoff always has
        # headroom (a fixed cap below cooldown_s would SHRINK on "doubling")
        self.cooldown_max_s = (float(cooldown_max_s)
                               if cooldown_max_s is not None
                               else max(100.0 * self.cooldown_s, 5.0))
        self.state = CLOSED
        self.failures = 0
        self.opened_at = 0.0
        self._cooldown = self.cooldown_s
        self._probing = False
        self.trips = 0
        self.probes = 0

    # -- gates --------------------------------------------------------------

    def allow(self, now: float) -> bool:
        """May a drained batch for this scene launch at ``now``? In OPEN,
        flips to HALF_OPEN (returning True exactly once — the probe) when
        the cooldown has elapsed."""
        if self.state == CLOSED:
            return True
        if self.state == OPEN:
            if now - self.opened_at < self._cooldown:
                return False
            self.state = HALF_OPEN
            self._probing = False
        # HALF_OPEN: one probe at a time
        if self._probing:
            return False
        self._probing = True
        self.probes += 1
        return True

    def submit_allowed(self, now: float) -> bool:
        """May a new request for this scene even be admitted at ``now``?
        False only while OPEN inside the cooldown — half-open admits (the
        queue feeds the probe)."""
        return not (self.state == OPEN
                    and now - self.opened_at < self._cooldown)

    def retry_after(self, now: float) -> float:
        """Cooldown remaining (the ``CircuitOpen.retry_after_s`` hint)."""
        if self.state != OPEN:
            return 0.0
        return max(0.0, self._cooldown - (now - self.opened_at))

    # -- outcomes -----------------------------------------------------------

    def record_success(self) -> None:
        self.failures = 0
        self._probing = False
        if self.state != CLOSED:
            self.state = CLOSED
            self._cooldown = self.cooldown_s        # full reset
        return None

    def record_failure(self, now: float) -> bool:
        """Record one batch failure; returns True when this trips (or
        re-trips) the breaker open."""
        self._probing = False
        if self.state == HALF_OPEN:
            # failed probe: back off geometrically
            self.state = OPEN
            self.opened_at = now
            self._cooldown = min(self._cooldown * 2.0, self.cooldown_max_s)
            self.trips += 1
            return True
        self.failures += 1
        if self.state == CLOSED and self.failures >= self.threshold:
            self.state = OPEN
            self.opened_at = now
            self.trips += 1
            return True
        return False
