"""Error taxonomy of the fault-tolerant serving layer (DESIGN.md
section 11).

Every admitted request resolves as exactly ONE of:

* a ``SearchResult`` (possibly flagged degraded, see ``quality.py``);
* ``QueryError``       — the input itself is unservable (NaN/inf rows,
                         sentinel-colliding coordinates, out-of-domain
                         when bounds are enforced). Raised *before* the
                         request can reach a device launch, so one
                         tenant's poisoned rows can never taint a
                         concatenated batch;
* ``DeadlineExceeded`` — the request's server-side deadline expired
                         while it waited in the admission queue; it is
                         dropped at bucket drain, before launch;
* ``Rejected``         — admission refused past the pending high-water
                         mark (defined in ``repro.serve.service``;
                         carries a retry-after estimate);
* ``CircuitOpen``      — the target scene's circuit breaker is open
                         (repeated launch failures); the scene is
                         isolated while other tenants keep draining.

``TransientFault`` is the marker mixin the retry policy keys on: a
launch failure that is transient (an injected fault, a transient
runtime error) is retried with exponential backoff + jitter; anything
else fails the batch's futures immediately.
"""
from __future__ import annotations


class TransientFault:
    """Marker mixin: failures that are worth retrying (bounded, with
    backoff). The fault-injection harness raises these; real transient
    launch errors can subclass or be wrapped."""


class InjectedFault(TransientFault, RuntimeError):
    """A deterministic fault injected by ``reliability.faults``.

    ``kind`` is the injection site ("launch", "compile", ...); ``site``
    the full decision key (site plus scope), ``n`` the per-site decision
    counter — together they identify the exact injection for replay.
    """

    def __init__(self, kind: str, site: str, n: int):
        super().__init__(f"injected {kind} fault (site={site}, n={n})")
        self.kind = kind
        self.site = site
        self.n = n


class QueryError(ValueError):
    """Structured input-validation failure (``api.validate_queries``).

    ``reasons`` maps reason -> offending row count (``"nan"``,
    ``"inf"``, ``"oob"``); ``rows`` lists the first offending row
    indices (bounded) so callers can pinpoint the poison.
    """

    def __init__(self, reasons: dict, rows, nq: int):
        self.reasons = dict(reasons)
        self.rows = list(rows)
        self.nq = int(nq)
        detail = ", ".join(f"{k}={v}" for k, v in self.reasons.items())
        super().__init__(
            f"unservable queries ({detail} of {nq} rows; first bad rows "
            f"{self.rows})")


class DeadlineExceeded(RuntimeError):
    """The request's server-side deadline expired before its bucket
    drained; it was dropped WITHOUT being launched."""

    def __init__(self, request_id: int, deadline: float, now: float):
        super().__init__(
            f"request {request_id} deadline expired "
            f"{(now - deadline) * 1e3:.1f}ms before drain; dropped unlaunched")
        self.request_id = request_id
        self.deadline = deadline


class Cancelled(RuntimeError):
    """The caller cancelled the future (``ServeFuture.cancel``); the
    request was dropped at bucket drain without being launched."""

    def __init__(self, request_id: int):
        super().__init__(f"request {request_id} cancelled by caller")
        self.request_id = request_id


class CircuitOpen(RuntimeError):
    """The scene's circuit breaker is open: recent drains against it
    failed ``threshold`` consecutive times, so it is isolated until the
    half-open probe succeeds. Retry after ``retry_after_s`` (or against
    another scene)."""

    def __init__(self, scene_id, retry_after_s: float):
        super().__init__(
            f"scene {scene_id!r} circuit breaker is open; retry after "
            f"~{retry_after_s * 1e3:.1f}ms")
        self.scene_id = scene_id
        self.retry_after_s = retry_after_s


def is_transient(exc: BaseException) -> bool:
    """The retry policy's predicate."""
    return isinstance(exc, TransientFault)
