"""repro.reliability — fault model, error taxonomy, and chaos tooling
for the serving layer (DESIGN.md section 11).

* ``errors``  — the request-outcome taxonomy: every admitted request
  resolves as exactly one of {result, ``QueryError``,
  ``DeadlineExceeded``, ``Rejected``, ``CircuitOpen``} (plus
  ``Cancelled`` for caller-cancelled futures);
* ``faults``  — the deterministic seeded fault-injection harness
  (``REPRO_FAULTS`` knob, :class:`FaultPlan`) the chaos tests and the
  CI chaos smoke drive;
* ``breaker`` — the per-scene circuit-breaker state machine;
* ``quality`` — per-response :class:`ResultQuality` flags derived from
  the device overflow/oob counters.
"""
from . import faults  # noqa: F401
from .breaker import CircuitBreaker  # noqa: F401
from .errors import (Cancelled, CircuitOpen, DeadlineExceeded,  # noqa: F401
                     InjectedFault, QueryError, TransientFault,
                     is_transient)
from .faults import FaultPlan  # noqa: F401
from .quality import ResultQuality  # noqa: F401

__all__ = [
    "Cancelled",
    "CircuitBreaker",
    "CircuitOpen",
    "DeadlineExceeded",
    "FaultPlan",
    "InjectedFault",
    "QueryError",
    "ResultQuality",
    "TransientFault",
    "faults",
    "is_transient",
]
