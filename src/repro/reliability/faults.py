"""Deterministic fault-injection harness (DESIGN.md section 11).

Chaos testing the serving layer needs failures that are *reproducible*:
the same seeded plan must inject the same faults at the same decision
points on every run, so a chaos trace that hangs a future is a test
case, not a flake. A :class:`FaultPlan` holds per-site injection rates;
each decision is a pure hash of ``(seed, site, decision counter)`` —
no hidden RNG state, no cross-site coupling, thread-safe.

Injection sites (each a named seam the production code already owns):

* ``launch``     — raise :class:`~.errors.InjectedFault` where a batch
                   is dispatched to the device (``serve.service`` drain,
                   ``core.executor.execute_async``);
* ``compile``    — raise at compile seams (``executor._get_launcher``
                   on a launcher-cache miss);
* ``straggler``  — sleep ``delay_s`` before the blocking result sync
                   (an artificial straggler the serve pump's
                   ``StragglerMonitor`` must flag, not hang on);
* ``poison``     — corrupt admitted query rows with NaN (what input
                   validation must catch before launch).

Activation: ``install(plan)`` for tests / ``scoped(plan)`` as a context
manager, or the ``REPRO_FAULTS`` knob for whole-process chaos runs::

    REPRO_FAULTS="launch:0.2,straggler:0.1,poison:0.05,seed:7" \
        python -m repro.launch.serve --trace short

Spec grammar: comma-separated ``site:rate`` pairs plus the optional
modifiers ``seed:<int>``, ``delay_ms:<float>`` (straggler sleep),
``scene:<id>`` (inject only against that scene — how a chaos test
poisons one tenant while others stay healthy) and ``budget:<int>``
(stop after N injections per site — deterministic "fail exactly once"
tests). With no plan installed every hook is a cheap no-op.
"""
from __future__ import annotations

import hashlib
import os
import threading
import time

import numpy as np

from .errors import InjectedFault

_SITES = ("launch", "compile", "straggler", "poison")


class FaultPlan:
    """A seeded, deterministic fault schedule."""

    def __init__(self, *, launch: float = 0.0, compile: float = 0.0,
                 straggler: float = 0.0, poison: float = 0.0,
                 seed: int = 0, delay_s: float = 0.005,
                 scene=None, budgets: dict | None = None):
        self.rates = {"launch": float(launch), "compile": float(compile),
                      "straggler": float(straggler), "poison": float(poison)}
        for site, rate in self.rates.items():
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"fault rate {site}:{rate} not in [0, 1]")
        self.seed = int(seed)
        self.delay_s = float(delay_s)
        self.scene = scene
        self.budgets = dict(budgets or {})
        self._counts: dict = {s: 0 for s in _SITES}      # decisions taken
        self._fired: dict = {s: 0 for s in _SITES}       # injections fired
        self._lock = threading.Lock()

    # -- decisions ----------------------------------------------------------

    def _uniform(self, site: str, n: int) -> float:
        h = hashlib.sha256(f"{self.seed}:{site}:{n}".encode()).digest()
        return int.from_bytes(h[:8], "big") / float(1 << 64)

    def decide(self, site: str, scene=None) -> int | None:
        """One deterministic decision at ``site``; returns the decision
        index when the fault fires, else None. Out-of-scope scenes and
        exhausted budgets never fire (and don't consume a decision for
        scoped-out scenes, so per-scene schedules stay independent of
        other tenants' traffic)."""
        rate = self.rates.get(site, 0.0)
        if rate <= 0.0:
            return None
        if self.scene is not None and scene != self.scene:
            return None
        with self._lock:
            n = self._counts[site]
            self._counts[site] = n + 1
            budget = self.budgets.get(site)
            if budget is not None and self._fired[site] >= budget:
                return None
            if self._uniform(site, n) >= rate:
                return None
            self._fired[site] += 1
            return n

    def stats(self) -> dict:
        with self._lock:
            return {"decisions": dict(self._counts),
                    "fired": dict(self._fired)}

    def spec(self) -> str:
        """The plan as a ``REPRO_FAULTS``-style spec string (logging)."""
        parts = [f"{s}:{r:g}" for s, r in self.rates.items() if r > 0]
        parts.append(f"seed:{self.seed}")
        if self.scene is not None:
            parts.append(f"scene:{self.scene}")
        return ",".join(parts)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse a ``REPRO_FAULTS`` spec string (see module docstring)."""
        kw: dict = {}
        budgets: dict = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if ":" not in part:
                raise ValueError(f"REPRO_FAULTS entry {part!r} is not "
                                 f"'key:value'")
            key, val = (s.strip() for s in part.split(":", 1))
            if key in _SITES:
                kw[key] = float(val)
            elif key == "seed":
                kw["seed"] = int(val)
            elif key == "delay_ms":
                kw["delay_s"] = float(val) / 1e3
            elif key == "scene":
                kw["scene"] = val
            elif key == "budget":
                for site in _SITES:
                    budgets[site] = int(val)
            else:
                raise ValueError(f"unknown REPRO_FAULTS key {key!r} "
                                 f"(sites: {', '.join(_SITES)}; modifiers: "
                                 f"seed, delay_ms, scene, budget)")
        return cls(**kw, budgets=budgets)


# ---------------------------------------------------------------------------
# process-wide activation
# ---------------------------------------------------------------------------

_PLAN: FaultPlan | None = None
_ENV_READ = False
_METRICS = None


def _metrics():
    global _METRICS
    if _METRICS is None:
        from .. import obs
        _METRICS = obs.metric_set("faults")
    return _METRICS


def configure(plan: FaultPlan | None = None, *,
              from_env: bool = False) -> FaultPlan | None:
    """Install ``plan`` (None deactivates), or re-read ``REPRO_FAULTS``."""
    global _PLAN, _ENV_READ
    if from_env:
        spec = os.environ.get("REPRO_FAULTS", "")
        _PLAN = FaultPlan.parse(spec) if spec else None
    else:
        _PLAN = plan
    _ENV_READ = True
    return _PLAN


install = configure


def active() -> FaultPlan | None:
    """The installed plan (lazily initialized from ``REPRO_FAULTS``)."""
    if not _ENV_READ:
        configure(from_env=True)
    return _PLAN


class scoped:
    """``with faults.scoped(plan): ...`` — install for a block (tests)."""

    def __init__(self, plan: FaultPlan | None):
        self.plan = plan

    def __enter__(self):
        self._prev, self._prev_read = _PLAN, _ENV_READ
        configure(self.plan)
        return self.plan

    def __exit__(self, *exc):
        global _PLAN, _ENV_READ
        _PLAN, _ENV_READ = self._prev, self._prev_read
        return False


# ---------------------------------------------------------------------------
# the hooks production code calls
# ---------------------------------------------------------------------------

def maybe_fail(site: str, scene=None) -> None:
    """Raise :class:`InjectedFault` when the plan schedules one here."""
    plan = active()
    if plan is None:
        return
    n = plan.decide(site, scene=scene)
    if n is not None:
        _metrics().count(f"injected_{site}")
        raise InjectedFault(site, f"{site}/{scene}" if scene is not None
                            else site, n)


def maybe_delay(scene=None) -> float:
    """Sleep the plan's straggler delay when scheduled; returns the
    injected delay in seconds (0.0 when none fired)."""
    plan = active()
    if plan is None:
        return 0.0
    n = plan.decide("straggler", scene=scene)
    if n is None:
        return 0.0
    _metrics().count("injected_straggler")
    time.sleep(plan.delay_s)
    return plan.delay_s


def maybe_poison(queries: np.ndarray, scene=None) -> np.ndarray:
    """Corrupt one row of ``queries`` with NaN when scheduled (returns a
    poisoned COPY; the caller's array is never mutated)."""
    plan = active()
    if plan is None or queries.size == 0:
        return queries
    n = plan.decide("poison", scene=scene)
    if n is None:
        return queries
    _metrics().count("injected_poison")
    out = np.array(queries, copy=True)
    out[n % out.shape[0]] = np.nan
    return out
