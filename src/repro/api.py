"""``repro.api`` — the functional pytree-first neighbor-search API.

Pure ``build_index / query / update_index`` core that composes under
``jax.jit``, ``jax.vmap``, and ``shard_map``; see ``repro/core/api.py``
and DESIGN.md section 8. The class-based surfaces (``NeighborSearch``,
``SimulationSession``) in ``repro.core`` are shims over this module.
"""
from .core.api import *  # noqa: F401,F403
from .core.api import __all__  # noqa: F401
