"""Training step factory: microbatched grad accumulation + AdamW.

Microbatching (``lax.scan`` over the leading microbatch axis) bounds
activation memory at large model scale: per-layer remat checkpoints are
held for one microbatch at a time. Gradients accumulate in f32.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.model import NO_SHARD, train_forward
from .optimizer import OptConfig, apply_updates

PyTree = Any


def make_train_step(cfg: ArchConfig, opt_cfg: OptConfig, *,
                    shard=NO_SHARD, remat: bool = True
                    ) -> Callable:
    """Returns ``train_step(params, opt_state, batch)``.

    ``batch`` arrays carry a leading microbatch axis: [n_micro, B_micro, ...]
    (n_micro=1 for small archs). The returned metrics include the mean loss.
    """

    def micro_grads(params, micro):
        loss, grads = jax.value_and_grad(
            lambda p: train_forward(p, micro, cfg, shard=shard,
                                    remat=remat))(params)
        return loss, grads

    def train_step(params, opt_state, batch):
        n_micro = jax.tree.leaves(batch)[0].shape[0]

        if n_micro == 1:
            micro = jax.tree.map(lambda a: a[0], batch)
            loss, grads = micro_grads(params, micro)
        else:
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, micro):
                loss_acc, gacc = carry
                loss, grads = micro_grads(params, micro)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                return (loss_acc + loss, gacc), None

            (loss_sum, gsum), _ = jax.lax.scan(body, (jnp.float32(0), zero),
                                               batch)
            loss = loss_sum / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, gsum)

        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig, *, shard=NO_SHARD) -> Callable:
    def eval_step(params, batch):
        micro = jax.tree.map(lambda a: a[0], batch)
        return train_forward(params, micro, cfg, shard=shard, remat=False)
    return eval_step
