from .optimizer import OptConfig, init_opt_state, apply_updates, opt_state_specs
from .train_step import make_train_step, make_eval_step
from .serve_step import make_prefill_step, make_decode_step, greedy_generate
from .checkpoint import CheckpointManager
from .fault_tolerance import ResilientLoop, StragglerMonitor, remesh
