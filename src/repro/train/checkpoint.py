"""Fault-tolerant checkpointing: atomic, resumable, retention-managed.

Design for the 1000+-node posture (DESIGN.md section 6):
  * save is write-to-temp + atomic rename (a crashed save never corrupts
    the latest checkpoint);
  * the manifest records step, data cursor, and RNG so restore resumes the
    exact stream position (synthetic_stream is a pure function of the
    cursor);
  * retention keeps the newest K checkpoints;
  * arrays are stored host-side .npz per pytree leaf path — mesh-shape
    agnostic, so an elastic restart onto a different mesh re-shards on
    device_put (see fault_tolerance.remesh).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(tree: PyTree, flat: dict[str, np.ndarray]) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    new = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        new.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), new)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def save(self, step: int, params: PyTree, opt_state: PyTree,
             extra: dict | None = None) -> str:
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
            np.savez(os.path.join(tmp, "opt_state.npz"),
                     **_flatten(opt_state))
            manifest = {"step": step, **(extra or {})}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)            # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._retain()
        return self._step_dir(step)

    def _retain(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.dir, d,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, params_like: PyTree, opt_like: PyTree,
                step: int | None = None
                ) -> tuple[PyTree, PyTree, dict]:
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint available"
        d = self._step_dir(step)
        with np.load(os.path.join(d, "params.npz")) as z:
            params = _unflatten_into(params_like, dict(z))
        with np.load(os.path.join(d, "opt_state.npz")) as z:
            opt = _unflatten_into(opt_like, dict(z))
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        return params, opt, manifest
