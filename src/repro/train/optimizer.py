"""In-repo AdamW with optional int8 block-quantized moments.

Quantized moments (blockwise absmax int8, like 8-bit Adam) are the
distributed-optimization memory trick that lets the 100B+ archs fit v5e HBM
at mesh scale: m and v shrink 4x vs f32. Moments inherit the parameter
sharding (FSDP-sharded params => sharded optimizer state: ZeRO-ish by
construction).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any
_QBLOCK = 256


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False
    warmup_steps: int = 100


# -- int8 blockwise quantization --------------------------------------------
#
# m: signed absmax int8 (linear error is benign — it scales the update).
# v: int8 in sqrt-space with a one-quant-step decode floor. Plain absmax on
# v zero-collapses small second moments inside a block, and m/(sqrt(0)+eps)
# explodes; the sqrt-space floor bounds every update by 127*|m|/blockmax
# instead (documented bias: tiny-v elements get conservatively smaller
# steps).
#
# LAYOUT: codes keep the PARAM's shape (blocks along the last axis, padded
# to the block size); scales drop the last axis to [..., n_blocks]. The
# moments therefore inherit the parameter's PartitionSpec verbatim — a flat
# [n_blocks, B] layout forces SPMD replicate-then-reshard of full-size f32
# gradients at every encode (measured: 5.4 TB/step of involuntary
# all-gathers on deepseek-671b; EXPERIMENTS.md Perf iteration 6).

def _pad_last(x: jax.Array) -> jax.Array:
    pad = (-x.shape[-1]) % _QBLOCK
    if pad:
        cfgpad = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, cfgpad)
    return x


def _qencode(x: jax.Array) -> dict[str, jax.Array]:
    if x.ndim == 0:
        x = x[None]
    xp = _pad_last(x)
    blocks = xp.reshape(*xp.shape[:-1], xp.shape[-1] // _QBLOCK, _QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    code = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"code": code.reshape(xp.shape),
            "scale": scale[..., 0].astype(jnp.float32)}


def _qdecode(q: dict[str, jax.Array], shape) -> jax.Array:
    code = q["code"]
    blocks = code.reshape(*code.shape[:-1], code.shape[-1] // _QBLOCK,
                          _QBLOCK)
    out = blocks.astype(jnp.float32) * q["scale"][..., None]
    out = out.reshape(code.shape)
    out = out[..., : shape[-1] if shape else 1]
    return out.reshape(shape)


def _qencode_sqrt(x: jax.Array) -> dict[str, jax.Array]:
    """Non-negative values (second moments), quantized in sqrt-space."""
    if x.ndim == 0:
        x = x[None]
    xp = _pad_last(jnp.sqrt(jnp.maximum(x, 0.0)))
    blocks = xp.reshape(*xp.shape[:-1], xp.shape[-1] // _QBLOCK, _QBLOCK)
    scale = jnp.maximum(jnp.max(blocks, axis=-1, keepdims=True) / 127.0,
                        1e-20)
    code = jnp.clip(jnp.round(blocks / scale), 0, 127).astype(jnp.int8)
    return {"code": code.reshape(xp.shape),
            "scale": scale[..., 0].astype(jnp.float32)}


def _qdecode_sqrt(q: dict[str, jax.Array], shape) -> jax.Array:
    # decode floor of one quant step: bounds updates for zero-collapsed v
    code = q["code"]
    blocks = code.reshape(*code.shape[:-1], code.shape[-1] // _QBLOCK,
                          _QBLOCK)
    root = jnp.maximum(blocks.astype(jnp.float32), 1.0) * \
        q["scale"][..., None]
    out = (root * root).reshape(code.shape)
    out = out[..., : shape[-1] if shape else 1]
    return out.reshape(shape)


# -- state -------------------------------------------------------------------

def init_opt_state(params: PyTree, cfg: OptConfig) -> PyTree:
    def zeros_like_moment(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _qencode(z) if cfg.quantize_moments else z

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_moment, params),
        "v": jax.tree.map(zeros_like_moment, params),
    }


def opt_state_specs(params: PyTree, cfg: OptConfig) -> PyTree:
    """ShapeDtypeStruct tree of the optimizer state (dry-run path)."""
    return jax.eval_shape(lambda p: init_opt_state(p, cfg), params)


def _global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def apply_updates(params: PyTree, grads: PyTree, state: PyTree,
                  cfg: OptConfig) -> tuple[PyTree, PyTree, dict]:
    """One AdamW step. Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, state["step"])
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        m_f = _qdecode(m, p.shape) if cfg.quantize_moments else m
        v_f = _qdecode_sqrt(v, p.shape) if cfg.quantize_moments else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * gf
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * gf * gf
        upd = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (upd + wd * p.astype(
            jnp.float32))
        new_m = _qencode(m_f) if cfg.quantize_moments else m_f
        new_v = _qencode_sqrt(v_f) if cfg.quantize_moments else v_f
        return new_p.astype(p.dtype), new_m, new_v

    is_q = lambda x: isinstance(x, dict) and "code" in x
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {
        "step": step,
        "m": tdef.unflatten([o[1] for o in out]),
        "v": tdef.unflatten([o[2] for o in out]),
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
