"""Fault tolerance + elasticity runtime (CPU-simulable).

Pieces a 1000+-node deployment needs, each testable here:
  * ``ResilientLoop`` — checkpoint/restart driver: on a step exception it
    restores the latest checkpoint and replays the data stream from the
    saved cursor (deterministic stream => exactly-once semantics).
  * ``StragglerMonitor`` — per-step deadline tracking with an EMA of step
    time; flags pods exceeding ``factor`` x EMA (on real fleets this feeds
    the scheduler; here it is exercised by tests with injected delays).
  * ``remesh`` — elastic re-sharding: checkpointed host arrays are
    mesh-shape agnostic, so scaling 256<->512 chips is device_put with the
    new mesh's NamedShardings.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from .checkpoint import CheckpointManager

PyTree = Any


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 3.0
    ema: float | None = None
    alpha: float = 0.1
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        """Record one step duration; returns True if it is a straggler."""
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = dt > self.factor * self.ema
        if is_straggler:
            self.flagged += 1
        else:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler


class ResilientLoop:
    """Run train steps with checkpoint/restart on failure."""

    def __init__(self, ckpt: CheckpointManager, *, save_every: int = 10,
                 max_restarts: int = 3):
        self.ckpt = ckpt
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.monitor = StragglerMonitor()
        self.restarts = 0

    def run(self, step_fn: Callable, params: PyTree, opt_state: PyTree,
            stream_fn: Callable[[int], Iterator], n_steps: int,
            start_step: int = 0):
        """``stream_fn(step)`` must return an iterator positioned at
        ``step`` (synthetic_stream(start_step=...)); ``step_fn`` raises on
        simulated node failure."""
        step = start_step
        stream = stream_fn(step)
        metrics_log = []
        while step < n_steps:
            batch = next(stream)
            t0 = time.perf_counter()
            try:
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    # no checkpoint yet: restart from the initial state
                    step = start_step
                    stream = stream_fn(step)
                    continue
                params, opt_state, manifest = self.ckpt.restore(
                    params, opt_state)
                step = manifest["step"]
                stream = stream_fn(step)
                continue
            self.monitor.observe(time.perf_counter() - t0)
            metrics_log.append(jax.device_get(metrics))
            step += 1
            if step % self.save_every == 0:
                self.ckpt.save(step, params, opt_state,
                               extra={"cursor": step})
        return params, opt_state, metrics_log


def remesh(tree: PyTree, mesh: Mesh, specs: PyTree) -> PyTree:
    """Elastic re-shard: place a host/arbitrary-sharded pytree onto ``mesh``
    with ``specs`` (PartitionSpec tree). Works across mesh shape changes
    because source arrays are fetched to host first."""
    def place(x, spec):
        hx = np.asarray(jax.device_get(x))
        return jax.device_put(hx, NamedSharding(mesh, spec))
    return jax.tree.map(place, tree, specs)
