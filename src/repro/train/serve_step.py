"""Serving steps: prefill (builds the KV cache) + decode (one token).

``decode_32k`` / ``long_500k`` dry-run cells lower ``decode_step`` with a
seq_len-sized cache, per the assignment brief.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.model import (NO_SHARD, decode_step, init_decode_cache,
                                init_params, train_forward, _run_layers,
                                _norm, layer_groups)
from repro.models import model as M
from repro.models import layers as L

PyTree = Any


def make_prefill_step(cfg: ArchConfig, *, shard=NO_SHARD) -> Callable:
    """Forward over the full prompt producing last-position logits.

    (The cache-writing prefill variant exists via decode_step with Sq>1; for
    the dry-run the compute-representative artifact is the full forward.)
    """

    def prefill(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = params["embed"][tokens]
        x = shard(x, "act_resid")
        if cfg.pos == "mrope":
            pos = batch["pos3"]
        else:
            pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.frontend == "vision_stub" and cfg.n_vision_tokens:
            nv = min(cfg.n_vision_tokens, s)
            x = jnp.concatenate(
                [batch["vision_embeds"].astype(x.dtype), x[:, nv:]], axis=1)
        x = M._run_layers(params, x, cfg, pos=pos, shard=shard, remat=False)
        x = M._norm(x, params["final_norm"], cfg.norm_eps)
        unembed = (params["embed"].T if cfg.tie_embeddings
                   else params["unembed"])
        logits = jnp.einsum("bd,dv->bv", x[:, -1], unembed,
                            preferred_element_type=jnp.float32)
        return shard(logits, "logits_last")

    return prefill


def make_decode_step(cfg: ArchConfig, *, shard=NO_SHARD) -> Callable:
    def decode(params, cache, tokens, pos3=None):
        return decode_step(params, cache, tokens, cfg, pos=pos3, shard=shard)
    return decode


def greedy_generate(params, cfg: ArchConfig, prompt: jax.Array,
                    max_new: int, cache_len: int,
                    dtype=jnp.float32) -> jax.Array:
    """Simple batched greedy loop (examples / tests)."""
    b = prompt.shape[0]
    cache = init_decode_cache(cfg, b, cache_len, dtype)
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))

    # feed the prompt one token at a time (prefill-by-decode; simple + exact)
    logits = None
    for i in range(prompt.shape[1]):
        logits, cache = step(params, cache, prompt[:, i: i + 1])
    outs = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(max_new):
        outs.append(tok)
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(outs, axis=1)
