"""OpenMetrics text exporter (DESIGN.md section 12).

Renders the aggregated metric registry — and the per-tenant SLO board —
in the OpenMetrics text exposition format, so the whole process scrapes
like a Prometheus target (pipe ``obs.export_openmetrics()`` to a file or
an HTTP handler; no server is bundled).

Mapping from the registry's metric kinds:

* **counter**   → ``counter`` family; sample name gets the mandatory
  ``_total`` suffix.
* **gauge**     → ``gauge`` family (the ``tick`` bookkeeping field is
  dropped — it is merge metadata, not a measurement).
* **histogram** → ``summary`` family: ``quantile``-labelled samples for
  p50/p95/p99 plus ``_sum`` and ``_count`` (the registry keeps a
  reservoir, not fixed buckets, so a summary is the honest rendering).

Metric names are ``repro_{component}_{name}`` with every
non-``[a-zA-Z0-9_]`` character collapsed to ``_``. Per-tenant SLO
families (``repro_slo_*``) carry a ``tenant`` label. Output ends with
the mandatory ``# EOF`` terminator; tests/test_obs_serve.py validates
the grammar line-by-line.
"""
from __future__ import annotations

import re

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")
_LABEL_ESC = str.maketrans({"\\": r"\\", '"': r"\"", "\n": r"\n"})


def _metric_name(component: str, name: str) -> str:
    base = _NAME_RE.sub("_", f"repro_{component}_{name}")
    if base[0].isdigit():
        base = "_" + base
    return base


def _fmt(v: float) -> str:
    f = float(v)
    if f != f:
        return "NaN"
    if f in (float("inf"), float("-inf")):
        return "+Inf" if f > 0 else "-Inf"
    return f"{int(f)}" if f.is_integer() else repr(f)


def _label(k: str, v: str) -> str:
    return f'{k}="{str(v).translate(_LABEL_ESC)}"'


def _emit_family(lines: list, fam: str, omtype: str,
                 samples: list) -> None:
    """samples: [(sample_name, label_str_or_empty, value)]."""
    lines.append(f"# TYPE {fam} {omtype}")
    for sname, labels, value in samples:
        lines.append(f"{sname}{labels} {_fmt(value)}")


def export_openmetrics(registry=None, board=None) -> str:
    """The full OpenMetrics text exposition (a ``str`` ending in
    ``# EOF``)."""
    from .registry import REGISTRY
    from . import slo as slo_mod
    reg = registry if registry is not None else REGISTRY
    brd = board if board is not None else slo_mod.BOARD

    lines: list = []
    for comp, metrics in sorted(reg.aggregate().items()):
        for name, snap in sorted(metrics.items()):
            fam = _metric_name(comp, name)
            kind = snap["kind"]
            if kind == "counter":
                _emit_family(lines, fam, "counter",
                             [(f"{fam}_total", "", snap["value"])])
            elif kind == "gauge":
                _emit_family(lines, fam, "gauge",
                             [(fam, "", snap["value"])])
            elif kind == "histogram":
                samples = [
                    (fam, "{" + _label("quantile", "0.5") + "}",
                     snap.get("p50", 0.0)),
                    (fam, "{" + _label("quantile", "0.95") + "}",
                     snap.get("p95", 0.0)),
                    (fam, "{" + _label("quantile", "0.99") + "}",
                     snap.get("p99", 0.0)),
                    (f"{fam}_sum", "", snap["sum"]),
                    (f"{fam}_count", "", snap["count"]),
                ]
                _emit_family(lines, fam, "summary", samples)

    snap = brd.snapshot()
    if snap:
        # one TYPE line per family, then every tenant's sample
        fams = [
            ("repro_slo_requests", "counter", "requests",
             lambda row: row["requests"]),
            ("repro_slo_attainment", "gauge", None,
             lambda row: row["attainment"]),
            ("repro_slo_burn_rate", "gauge", None,
             lambda row: row["burn_rate"]),
        ]
        for fam, omtype, _key, get in fams:
            sname = fam + ("_total" if omtype == "counter" else "")
            _emit_family(
                lines, fam, omtype,
                [(sname, "{" + _label("tenant", tenant) + "}", get(row))
                 for tenant, row in snap.items()])
        _emit_family(
            lines, "repro_slo_outcomes", "counter",
            [("repro_slo_outcomes_total",
              "{" + _label("tenant", tenant) + "," +
              _label("outcome", oc) + "}", n)
             for tenant, row in snap.items()
             for oc, n in sorted(row["outcomes"].items())])
        lat_samples = []
        for tenant, row in snap.items():
            lat = row["latency"]
            if not lat.get("count"):
                continue
            tl = _label("tenant", tenant)
            lat_samples += [
                ("repro_slo_latency_seconds",
                 "{" + tl + "," + _label("quantile", "0.5") + "}",
                 lat.get("p50", 0.0)),
                ("repro_slo_latency_seconds",
                 "{" + tl + "," + _label("quantile", "0.99") + "}",
                 lat.get("p99", 0.0)),
                ("repro_slo_latency_seconds_sum", "{" + tl + "}",
                 lat.get("sum", 0.0)),
                ("repro_slo_latency_seconds_count", "{" + tl + "}",
                 lat.get("count", 0)),
            ]
        if lat_samples:
            _emit_family(lines, "repro_slo_latency_seconds", "summary",
                         lat_samples)

    lines.append("# EOF")
    return "\n".join(lines) + "\n"
