"""Per-tenant SLO accounting (DESIGN.md section 12).

The serving registry (section 9) answers "what did the whole process
do"; this module answers the multi-tenant question the AMR/skew papers
motivate: *which tenant* is seeing the latency, and who is burning an
error budget. A process-wide :class:`SLOBoard` keeps one windowed
good/bad ledger per tenant (tenant == serve scene id), fed by the
service on every terminal outcome:

* ``ok`` / ``degraded``   — resolved futures (degraded = admitted under
  the overload ladder); *good* iff the end-to-end latency met the
  tenant's :class:`SLOTarget` threshold (or no target is armed);
* ``expired`` / ``rejected`` / ``circuit_open`` / ``error`` — *bad*.

Targets are declarative: ``SLOTarget(latency_s, objective, window_s)``
reads "``objective`` of requests in any ``window_s`` window resolve ok
within ``latency_s``". ``attainment(tenant)`` is the windowed good
fraction; ``burn_rate(tenant)`` the classic error-budget burn —
``bad_fraction / (1 - objective)``, >1 meaning the budget is burning
faster than the SLO allows. The default target comes from the
``REPRO_SLO`` knob (``latency_ms:250,objective:0.99,window_s:300``);
per-tenant overrides via :func:`set_target`.

The board always counts (outcome tallies are what the chaos gate's
per-tenant table and ``obs_top`` render); only the *gating* semantics
need a target. State is component-local and registers with the
``obs.lifecycle`` reset hook, so ``obs.reset()`` clears it.
"""
from __future__ import annotations

import os
import threading
import time

from .lifecycle import on_reset
from .registry import Histogram

#: outcomes that count toward the good side of the ledger (latency
#: permitting); everything else is bad. ``cancelled`` is deliberately
#: absent from both — a caller that gave up does not burn server budget.
GOOD_OUTCOMES = ("ok", "degraded")
BAD_OUTCOMES = ("expired", "rejected", "circuit_open", "error")
OUTCOMES = GOOD_OUTCOMES + BAD_OUTCOMES

_EVENTS_MAX = 4096      # windowed events kept per tenant


class SLOTarget:
    """One declarative objective: ``objective`` of requests within any
    ``window_s`` window resolve within ``latency_s``."""

    __slots__ = ("latency_s", "objective", "window_s")

    def __init__(self, latency_s: float = 0.25, objective: float = 0.99,
                 window_s: float = 300.0):
        self.latency_s = float(latency_s)
        self.objective = float(objective)
        self.window_s = float(window_s)
        if not 0.0 < self.objective <= 1.0:
            raise ValueError(f"objective {objective} not in (0, 1]")
        if self.latency_s <= 0.0 or self.window_s <= 0.0:
            raise ValueError("latency_s and window_s must be > 0")

    def error_budget(self) -> float:
        return 1.0 - self.objective

    def spec(self) -> str:
        return (f"latency_ms:{self.latency_s * 1e3:g},"
                f"objective:{self.objective:g},"
                f"window_s:{self.window_s:g}")

    @classmethod
    def parse(cls, spec: str) -> "SLOTarget":
        """Parse a ``REPRO_SLO`` spec string (see module docstring)."""
        kw: dict = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if ":" not in part:
                raise ValueError(f"REPRO_SLO entry {part!r} is not "
                                 f"'key:value'")
            key, val = (s.strip() for s in part.split(":", 1))
            if key == "latency_ms":
                kw["latency_s"] = float(val) / 1e3
            elif key in ("latency_s", "objective", "window_s"):
                kw[key] = float(val)
            else:
                raise ValueError(
                    f"unknown REPRO_SLO key {key!r} (expected latency_ms, "
                    f"latency_s, objective, window_s)")
        return cls(**kw)

    def __repr__(self):
        return f"SLOTarget({self.spec()})"


class _TenantState:
    """One tenant's ledger: windowed (t, good) events, lifetime outcome
    tallies, and a latency histogram for the per-tenant percentiles."""

    __slots__ = ("events", "outcomes", "latency", "occupancy")

    def __init__(self):
        import collections
        self.events: "collections.deque" = collections.deque(
            maxlen=_EVENTS_MAX)
        self.outcomes: dict = {k: 0 for k in OUTCOMES}
        self.latency = Histogram()
        self.occupancy = Histogram()


class SLOBoard:
    """Process-wide per-tenant SLO ledger (one instance: ``BOARD``)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._tenants: dict = {}
        self._targets: dict = {}
        self._default: SLOTarget | None = None
        self._env_read = False

    # -- configuration ------------------------------------------------------

    def configure(self, target: SLOTarget | None = None, *,
                  from_env: bool = False) -> SLOTarget | None:
        """Set the default target (None disarms gating), or re-read the
        ``REPRO_SLO`` knob."""
        with self._lock:
            if from_env:
                spec = os.environ.get("REPRO_SLO", "")
                self._default = SLOTarget.parse(spec) if spec else None
            else:
                self._default = target
            self._env_read = True
            return self._default

    def default_target(self) -> SLOTarget | None:
        with self._lock:
            if not self._env_read:
                spec = os.environ.get("REPRO_SLO", "")
                self._default = SLOTarget.parse(spec) if spec else None
                self._env_read = True
            return self._default

    def set_target(self, tenant, target: SLOTarget) -> None:
        with self._lock:
            self._targets[str(tenant)] = target

    def target(self, tenant) -> SLOTarget | None:
        t = self._targets.get(str(tenant))
        return t if t is not None else self.default_target()

    # -- recording ----------------------------------------------------------

    def record(self, tenant, outcome: str, latency_s: float | None = None,
               *, now: float | None = None,
               occupancy: float | None = None) -> None:
        """Attribute one terminal request outcome to ``tenant``.
        ``latency_s`` is the end-to-end latency of a resolved future
        (None for outcomes that never resolved). Unknown outcome names
        count as ``error`` rather than raising — the board must never
        take the serving path down."""
        tenant = str(tenant)
        if outcome not in OUTCOMES:
            outcome = "error"
        tgt = self.target(tenant)
        good = outcome in GOOD_OUTCOMES and (
            latency_s is None or tgt is None or latency_s <= tgt.latency_s)
        t = time.monotonic() if now is None else float(now)
        with self._lock:
            st = self._tenants.get(tenant)
            if st is None:
                st = self._tenants[tenant] = _TenantState()
            st.events.append((t, good))
            st.outcomes[outcome] += 1
            if latency_s is not None:
                st.latency.observe(latency_s)
            if occupancy is not None:
                st.occupancy.observe(occupancy)

    # -- reading ------------------------------------------------------------

    def tenants(self) -> list:
        with self._lock:
            return sorted(self._tenants)

    def _window_counts(self, st: _TenantState, window_s: float,
                       now: float) -> tuple[int, int]:
        lo = now - window_s
        good = bad = 0
        for t, g in st.events:
            if t < lo:
                continue
            if g:
                good += 1
            else:
                bad += 1
        return good, bad

    def attainment(self, tenant, now: float | None = None) -> float:
        """Windowed good fraction for ``tenant`` (1.0 with no traffic —
        an idle tenant is not out of SLO)."""
        tenant = str(tenant)
        with self._lock:
            st = self._tenants.get(tenant)
        if st is None:
            return 1.0
        tgt = self.target(tenant)
        window = tgt.window_s if tgt is not None else float("inf")
        now = time.monotonic() if now is None else float(now)
        good, bad = self._window_counts(st, window, now)
        total = good + bad
        return good / total if total else 1.0

    def burn_rate(self, tenant, now: float | None = None) -> float:
        """Error-budget burn in the window: bad fraction over the
        target's error budget. 0 with no traffic or no bad events;
        ``inf`` when bad events exist against a zero budget
        (objective == 1)."""
        tenant = str(tenant)
        with self._lock:
            st = self._tenants.get(tenant)
        if st is None:
            return 0.0
        tgt = self.target(tenant)
        window = tgt.window_s if tgt is not None else float("inf")
        now = time.monotonic() if now is None else float(now)
        good, bad = self._window_counts(st, window, now)
        total = good + bad
        if total == 0 or bad == 0:
            return 0.0
        budget = tgt.error_budget() if tgt is not None else 1.0
        frac = bad / total
        return frac / budget if budget > 0 else float("inf")

    def snapshot(self, now: float | None = None) -> dict:
        """{tenant: {outcomes, requests, attainment, burn_rate, target,
        latency percentiles, occupancy p50}} — the machine-readable
        per-tenant table (obs_top, openmetrics, the chaos gate)."""
        now = time.monotonic() if now is None else float(now)
        out = {}
        for tenant in self.tenants():
            with self._lock:
                st = self._tenants[tenant]
                outcomes = dict(st.outcomes)
                lat = st.latency.snapshot()
                occ = st.occupancy
                occ_p50 = occ.percentiles()["p50"] if occ.count else None
            tgt = self.target(tenant)
            out[tenant] = {
                "outcomes": outcomes,
                "requests": sum(outcomes.values()),
                "attainment": self.attainment(tenant, now=now),
                "burn_rate": self.burn_rate(tenant, now=now),
                "target": tgt.spec() if tgt is not None else None,
                "objective": tgt.objective if tgt is not None else None,
                "latency": lat,
                "occupancy_p50": occ_p50,
            }
        return out

    def summary(self, now: float | None = None) -> str:
        """Human-readable per-tenant table (the serve-figure and chaos
        gate rendering)."""
        snap = self.snapshot(now=now)
        lines = ["# per-tenant SLO",
                 f"# {'tenant':<14}{'req':>6}{'ok':>6}{'degr':>6}{'expd':>6}"
                 f"{'rej':>6}{'copen':>7}{'err':>5}{'attain':>8}{'obj':>7}"
                 f"{'burn':>7}{'p50_ms':>9}{'p99_ms':>9}"]
        if not snap:
            lines.append("# (no tenant traffic recorded)")
        for tenant, row in snap.items():
            oc, lat = row["outcomes"], row["latency"]
            obj = f"{row['objective']:.3f}" if row["objective"] else "-"
            burn = row["burn_rate"]
            lines.append(
                f"# {tenant:<14}{row['requests']:>6}{oc['ok']:>6}"
                f"{oc['degraded']:>6}{oc['expired']:>6}{oc['rejected']:>6}"
                f"{oc['circuit_open']:>7}{oc['error']:>5}"
                f"{row['attainment']:>8.3f}{obj:>7}"
                f"{('inf' if burn == float('inf') else f'{burn:.2f}'):>7}"
                f"{lat.get('p50', 0.0) * 1e3:>9.2f}"
                f"{lat.get('p99', 0.0) * 1e3:>9.2f}")
        return "\n".join(lines)

    def violations(self, now: float | None = None) -> dict:
        """{tenant: (attainment, objective)} for every tenant currently
        below its armed objective — the chaos-gate predicate. Empty when
        no target is armed."""
        out = {}
        for tenant in self.tenants():
            tgt = self.target(tenant)
            if tgt is None:
                continue
            att = self.attainment(tenant, now=now)
            if att < tgt.objective:
                out[tenant] = (att, tgt.objective)
        return out

    def reset(self) -> None:
        """Clear every tenant ledger and per-tenant target override
        (the default/env target survives — it is configuration, not
        state). Registered with ``obs.lifecycle.on_reset``."""
        with self._lock:
            self._tenants.clear()
            self._targets.clear()


BOARD = SLOBoard()
on_reset(BOARD.reset)

# module-level conveniences (the service call sites)
configure = BOARD.configure
default_target = BOARD.default_target
set_target = BOARD.set_target
record = BOARD.record
attainment = BOARD.attainment
burn_rate = BOARD.burn_rate
snapshot = BOARD.snapshot
summary = BOARD.summary
violations = BOARD.violations
