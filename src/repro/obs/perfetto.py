"""Perfetto / Chrome ``trace_event`` exporter (DESIGN.md section 12).

Converts the host span ring into the Trace Event JSON format that
``ui.perfetto.dev`` and ``chrome://tracing`` open directly, so a traced
serve run can be inspected on the same timeline as a ``jax.profiler``
capture. Each span becomes one complete event (``"ph": "X"``) with:

* ``ts``/``dur`` in microseconds on the span's ``perf_counter`` clock
  (``t0_s`` — relative placement is exact, absolute epoch is not);
* ``tid`` = the recording thread (so the submit thread, pump thread and
  caller threads land on separate tracks);
* ``args`` = the span's path, trace id (request-scoped spans) or
  ``trace_ids`` (batch-granular spans), and every recorded attribute —
  Perfetto's query/filter UI works over these.

Pure host-side post-processing over ``recent_spans()``; exporting never
touches device programs.
"""
from __future__ import annotations

import json
import os

from . import tracing


def to_trace_events(spans: list | None = None) -> dict:
    """Build the ``{"traceEvents": [...]}`` document (no file I/O)."""
    events = []
    pid = os.getpid()
    for rec in (tracing.recent_spans() if spans is None else spans):
        if rec.get("type", "span") != "span":
            continue
        args = {"path": rec.get("path", rec.get("name", ""))}
        if "trace" in rec:
            args["trace"] = rec["trace"]
        for k, v in (rec.get("attrs") or {}).items():
            args[k] = v
        events.append({
            "name": rec.get("name", "span"),
            "cat": "repro",
            "ph": "X",
            "ts": rec.get("t0_s", 0.0) * 1e6,
            "dur": rec.get("dur_s", 0.0) * 1e6,
            "pid": pid,
            "tid": rec.get("tid", 0),
            "args": args,
        })
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_perfetto(path: str | None = None,
                    spans: list | None = None) -> str:
    """Write the span ring (or an explicit span list) as Trace Event
    JSON; returns the path written (default ``repro_perfetto.json``)."""
    out = path or "repro_perfetto.json"
    with open(out, "w") as fh:
        json.dump(to_trace_events(spans), fh)
    return out
