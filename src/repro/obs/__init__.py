"""repro.obs — unified telemetry: metrics registry, span tracing,
device-resident counters (DESIGN.md section 9).

Quickstart::

    import os; os.environ["REPRO_TRACE"] = "1"
    import repro.obs as obs
    obs.configure()                    # pick up the knob (or pass mode=)
    ... run queries / session steps ...
    print(obs.summary())               # unified text table
    obs.export_jsonl("telemetry.jsonl")  # spans + metrics, one JSON/line
"""
from .registry import (REGISTRY, Counter, Gauge, Histogram,  # noqa: F401
                       MetricSet, Registry)
from .tracing import (configure, export_jsonl, recent_spans,  # noqa: F401
                      record_span, span, trace_enabled, trace_mode,
                      trace_path)
from .device import (TELEM_HEADER, level_occupancy,  # noqa: F401
                     pack_step_telemetry, unpack_step_telemetry)


def metric_set(component: str) -> MetricSet:
    """New instance-scoped MetricSet registered with the global registry."""
    return REGISTRY.metric_set(component)


def summary() -> str:
    """Text table of every metric in the global registry."""
    return REGISTRY.summary()


def metrics_dict() -> dict:
    """The unified metric schema ({"schema": "repro.obs/v1", "metrics":
    [...]}) consumed by benchmarks/ and scripts/check_bench.py."""
    return REGISTRY.metrics_dict()


def reset() -> None:
    """Clear the global registry and the span ring buffer (tests)."""
    from . import tracing
    REGISTRY.reset()
    tracing.reset()
