"""repro.obs — unified telemetry: metrics registry, span tracing,
device-resident counters (DESIGN.md section 9), and the request-scoped
layer (section 12): trace context, per-tenant SLOs, flight recorder,
Perfetto/OpenMetrics exporters.

Quickstart::

    import os; os.environ["REPRO_TRACE"] = "1"
    import repro.obs as obs
    obs.configure()                    # pick up the knob (or pass mode=)
    ... run queries / session steps ...
    print(obs.summary())               # unified text table
    obs.export_jsonl("telemetry.jsonl")  # spans + metrics, one JSON/line
    obs.export_perfetto("trace.json")  # open in ui.perfetto.dev
    print(obs.export_openmetrics())    # Prometheus-style scrape text
"""
from .registry import (REGISTRY, Counter, Gauge, Histogram,  # noqa: F401
                       MetricSet, Registry)
from .tracing import (configure, current_trace, export_jsonl,  # noqa: F401
                      recent_spans, record_span, span, timeline,
                      trace_enabled, trace_mode, trace_path, trace_scope)
from .device import (TELEM_HEADER, level_occupancy,  # noqa: F401
                     pack_step_telemetry, unpack_step_telemetry)
from .lifecycle import on_reset, run_reset_hooks  # noqa: F401
from .perfetto import export_perfetto, to_trace_events  # noqa: F401
from .openmetrics import export_openmetrics  # noqa: F401
from . import slo, flight  # noqa: F401  (registers their reset hooks)


def metric_set(component: str) -> MetricSet:
    """New instance-scoped MetricSet registered with the global registry."""
    return REGISTRY.metric_set(component)


def summary() -> str:
    """Text table of every metric in the global registry."""
    return REGISTRY.summary()


def metrics_dict() -> dict:
    """The unified metric schema ({"schema": "repro.obs/v1", "metrics":
    [...]}) consumed by benchmarks/ and scripts/check_bench.py."""
    return REGISTRY.metrics_dict()


def reset() -> None:
    """Clear the global registry, the span ring buffer, and every
    component-local state registered via :func:`on_reset` (SLO windows,
    flight ring) — so back-to-back test scenarios start clean."""
    from . import tracing
    REGISTRY.reset()
    tracing.reset()
    run_reset_hooks()
