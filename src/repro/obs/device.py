"""Device-resident telemetry counters (DESIGN.md section 9).

The sessions' one-host-sync-per-step contract (DESIGN.md sections 6/7)
says the ONLY per-step blocking transfer is a single packed scalar of
control flags. Telemetry must not add a second sync — so instead of
fetching counters separately, the step programs pack them INTO that one
transfer: the flags scalar widens to a small int32 vector

    [flags, overflow, oob, disp_bits, migrated, halo,
     occ_0, ..., occ_{L-1}]

where ``disp_bits`` is the f32 max-squared-displacement bitcast to int32
(lossless; unpacked host-side with a view), and ``occ_i`` counts query
tiles landing on ladder level ``i`` this step — the escalation-occupancy
histogram that tells the autotuner whether the ladder is sized right.
``migrated`` / ``halo`` are populated by the sharded session (zero for
single-device sessions).

One ``device_get`` of this vector is still exactly one host sync; the
host_syncs counter is unchanged, asserted by tests/test_obs.py. The
vector is computed unconditionally inside the traced step (a handful of
scalar ops — negligible next to the search itself), so the jaxpr is
identical whether host-side telemetry recording is on or off.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# header slots before the per-level occupancy tail
TELEM_FLAGS = 0
TELEM_OVERFLOW = 1
TELEM_OOB = 2
TELEM_DISP_BITS = 3
TELEM_MIGRATED = 4
TELEM_HALO = 5
TELEM_HEADER = 6


def level_occupancy(tile_levels: Array, n_levels: int) -> Array:
    """Per-ladder-level query-tile occupancy histogram [n_levels] int32.

    ``tile_levels`` is the plan's per-tile escalation level (core/api.py);
    the histogram is the device-side view of how the launch ladder is
    being used — all-tail means the ladder is too short, all-head means
    the windows are oversized.
    """
    return jnp.bincount(tile_levels.astype(jnp.int32).reshape(-1),
                        length=n_levels).astype(jnp.int32)


def pack_step_telemetry(flags: Array, *, overflow: Array, oob: Array,
                        max_disp2: Array, occupancy: Array,
                        migrated: Array | None = None,
                        halo: Array | None = None) -> Array:
    """Pack per-step counters into one int32 vector [TELEM_HEADER + L].

    Traced inside the step program; every argument is a scalar int32/f32
    device value except ``occupancy`` [L] int32.
    """
    i32 = jnp.int32
    zero = jnp.zeros((), i32)
    disp_bits = jax.lax.bitcast_convert_type(
        jnp.asarray(max_disp2, jnp.float32).reshape(()), i32)
    head = jnp.stack([
        jnp.asarray(flags, i32).reshape(()),
        jnp.asarray(overflow, i32).reshape(()),
        jnp.asarray(oob, i32).reshape(()),
        disp_bits,
        zero if migrated is None else jnp.asarray(migrated, i32).reshape(()),
        zero if halo is None else jnp.asarray(halo, i32).reshape(()),
    ])
    return jnp.concatenate([head, occupancy.astype(i32).reshape(-1)])


def unpack_step_telemetry(vec) -> dict:
    """Host-side unpack of a fetched telemetry vector (np.ndarray or a
    just-device_get results of pack_step_telemetry).

    Returns plain Python numbers: flags, overflow, oob, max_disp2 (f32
    recovered from its bit pattern), migrated, halo, and the occupancy
    list."""
    v = np.asarray(vec, np.int32).reshape(-1)
    return {
        "flags": int(v[TELEM_FLAGS]),
        "overflow": int(v[TELEM_OVERFLOW]),
        "oob": int(v[TELEM_OOB]),
        "max_disp2": float(v[TELEM_DISP_BITS:TELEM_DISP_BITS + 1]
                           .view(np.float32)[0]),
        "migrated": int(v[TELEM_MIGRATED]),
        "halo": int(v[TELEM_HALO]),
        "occupancy": [int(x) for x in v[TELEM_HEADER:]],
    }
