"""Flight recorder: bounded post-mortem state (DESIGN.md section 12).

Telemetry answers "how is the system doing"; the flight recorder answers
"what was the system doing *in the seconds before it broke*". It keeps a
bounded in-memory ring of structured events the serving stack feeds
continuously — drain reports, breaker transitions, retries, degradation
decisions — and on a reliability failure path (breaker open, pump crash,
hung future, ``QueryError``) dumps a single post-mortem JSON combining:

* the event ring (most recent ``_EVENTS_MAX`` events),
* the tail of the span ring (``recent_spans()``, trace ids included),
* the full aggregated metric registry,
* the per-tenant SLO board snapshot.

Knobs (DESIGN.md section 4): ``REPRO_FLIGHT`` (unset/0 = disabled — the
ring still records, dumps are suppressed), ``REPRO_FLIGHT_PATH`` (dump
path, default ``repro_flight.json``; an existing file is overwritten —
the *last* crash wins, like a real FDR).

The ring registers with ``obs.lifecycle.on_reset`` so back-to-back test
scenarios start clean; enablement/path are configuration and survive
``obs.reset()``.
"""
from __future__ import annotations

import collections
import json
import os
import threading
import time

from .lifecycle import on_reset
from . import tracing

_EVENTS_MAX = 512
_SPAN_TAIL = 2048       # spans included in a dump


def _parse_bool(val: str | None) -> bool:
    return (val or "").strip().lower() not in ("", "0", "off", "false", "no")


class FlightRecorder:
    """Bounded ring of recent serving events + one-shot post-mortem dump
    (one instance: ``RECORDER``)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: collections.deque = collections.deque(
            maxlen=_EVENTS_MAX)
        self._enabled = _parse_bool(os.environ.get("REPRO_FLIGHT"))
        self._path = os.environ.get("REPRO_FLIGHT_PATH",
                                    "repro_flight.json")
        self._dumps = 0

    # -- configuration ------------------------------------------------------

    def configure(self, enabled: bool | None = None,
                  path: str | None = None) -> None:
        """Set enablement/path at runtime; with no arguments, re-reads
        ``REPRO_FLIGHT`` / ``REPRO_FLIGHT_PATH``."""
        with self._lock:
            if enabled is None and path is None:
                self._enabled = _parse_bool(os.environ.get("REPRO_FLIGHT"))
                self._path = os.environ.get("REPRO_FLIGHT_PATH",
                                            "repro_flight.json")
                return
            if enabled is not None:
                self._enabled = bool(enabled)
            if path is not None:
                self._path = path

    def enabled(self) -> bool:
        return self._enabled

    def path(self) -> str:
        return self._path

    # -- recording ----------------------------------------------------------

    def note(self, kind: str, **payload) -> None:
        """Append one structured event to the ring (always, even when
        dumping is disabled — enabling REPRO_FLIGHT mid-flight still
        yields history). Payload values must be JSON-encodable; anything
        exotic is stringified."""
        rec = {"t": time.time(), "kind": kind}
        for k, v in payload.items():
            if isinstance(v, (int, float, str, bool, type(None), list,
                              dict)):
                rec[k] = v
            else:
                rec[k] = str(v)
        with self._lock:
            self._events.append(rec)

    def events(self) -> list:
        with self._lock:
            return list(self._events)

    def dump_count(self) -> int:
        with self._lock:
            return self._dumps

    # -- the post-mortem ----------------------------------------------------

    def dump(self, reason: str, path: str | None = None) -> str | None:
        """Write the post-mortem JSON; returns the path, or None when
        disabled (and no explicit ``path`` forces it). Never raises —
        a flight recorder that crashes the crashing service is useless;
        failures are recorded as an event and swallowed."""
        with self._lock:
            if not self._enabled and path is None:
                return None
            out = path or self._path
        try:
            from .registry import REGISTRY
            from . import slo
            doc = {
                "schema": "repro.obs/flight-v1",
                "reason": reason,
                "wall_time": time.time(),
                "pid": os.getpid(),
                "events": self.events(),
                "spans": tracing.recent_spans()[-_SPAN_TAIL:],
                "metrics": REGISTRY.metrics_dict(),
                "slo": slo.snapshot(),
            }
            tmp = out + ".tmp"
            with open(tmp, "w") as fh:
                json.dump(doc, fh, sort_keys=True)
            os.replace(tmp, out)
            with self._lock:
                self._dumps += 1
            return out
        except Exception as exc:  # pragma: no cover - defensive
            self.note("flight_dump_failed", reason=reason, error=str(exc))
            return None

    def reset(self) -> None:
        """Clear the event ring and dump counter (registered with
        ``obs.lifecycle.on_reset``); enablement/path are configuration
        and survive."""
        with self._lock:
            self._events.clear()
            self._dumps = 0


RECORDER = FlightRecorder()
on_reset(RECORDER.reset)

# module-level conveniences (the service call sites)
configure = RECORDER.configure
enabled = RECORDER.enabled
note = RECORDER.note
events = RECORDER.events
dump = RECORDER.dump
dump_count = RECORDER.dump_count
