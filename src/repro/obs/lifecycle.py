"""Reset-safety registration hook (DESIGN.md section 12).

``repro.obs.reset()`` clears the metrics registry and the span ring —
but observability components introduced on top of them (the per-tenant
SLO board, the flight-recorder ring, any future windowed state) own
state the core reset cannot see. Instead of ``reset()`` growing an
import of every such module, components register their own reset
callable here at import time::

    from .lifecycle import on_reset
    on_reset(BOARD.reset)

``obs.reset()`` then runs every registered hook after clearing the core
state, so two back-to-back test scenarios always start from clean
counters (the regression tests/test_obs_serve.py pins).
"""
from __future__ import annotations

import threading

_lock = threading.Lock()
_HOOKS: list = []


def on_reset(fn) -> None:
    """Register ``fn()`` to run on every ``repro.obs.reset()``.
    Idempotent: registering the same callable twice keeps one entry."""
    with _lock:
        if fn not in _HOOKS:
            _HOOKS.append(fn)


def run_reset_hooks() -> int:
    """Run every registered hook (called by ``obs.reset``); returns the
    hook count. A hook that raises propagates — a reset that silently
    half-works is worse than a loud test failure."""
    with _lock:
        hooks = list(_HOOKS)
    for fn in hooks:
        fn()
    return len(hooks)
