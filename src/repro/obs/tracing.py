"""Span tracing (DESIGN.md section 9).

``span(name, **attrs)`` is a nestable context manager that measures a
host-side stage and — always, independent of any knob — enters a
``jax.profiler.TraceAnnotation`` so the same stage shows up in XLA/perfetto
profiles. Host-side *recording* is gated by the ``REPRO_TRACE`` env knob
(DESIGN.md section 4 convention):

* unset / ``0`` / ``off``  — spans are timed-and-dropped (near-zero cost);
* ``1`` / ``log``          — spans are kept in an in-memory ring buffer
  (``recent_spans()``) and logged at DEBUG;
* ``2`` / ``jsonl`` / a path ending in ``.jsonl`` — spans additionally
  stream to a JSONL file (default ``repro_trace.jsonl``, overridable via
  ``REPRO_TRACE_PATH`` or by giving the path as the knob value itself).

Span taxonomy (fixed, so dashboards and tests can rely on the names):
top-level ``query`` (executor) and ``step`` (sessions); children ``plan``,
``compile``, ``launch``, ``sync``. The serving stack (DESIGN.md section
12) adds the request lifecycle: ``admit``/``admit/enqueue`` on the
submit path and ``drain``/``stage``/``launch``/``sync``/``split``/
``resolve`` on the drain path. Nesting is tracked per-thread; a span
record carries its slash-joined path (``step/launch/compile``), its
start time ``t0_s`` (``time.perf_counter`` clock — the clock the
Perfetto exporter converts to microseconds), and the recording thread's
``tid``.

**Trace context** (section 12): ``with trace_scope("req-000042"): ...``
pins a per-thread request id; every span recorded inside the scope (or
given an explicit ``trace=...`` attribute) carries it as the top-level
``trace`` field, and batch-granular spans carry the ``trace_ids`` list
attribute instead. ``timeline(trace_id)`` filters the ring down to one
request's spans in start-time order — the per-request reconstruction
``export_jsonl`` consumers and ``obs/perfetto.py`` build on.

Crucially, nothing here touches what gets *traced by JAX*: device
programs are identical with tracing on or off (asserted by
tests/test_obs.py jaxpr-parity tests). Only host bookkeeping differs.
"""
from __future__ import annotations

import collections
import json
import logging
import os
import threading
import time

import jax

logger = logging.getLogger("repro.obs")

_RING_MAX = 10_000

_state_lock = threading.Lock()
_mode = "off"                   # "off" | "log" | "jsonl"
_path = "repro_trace.jsonl"
_fh = None                      # lazily-opened JSONL handle
_ring: collections.deque = collections.deque(maxlen=_RING_MAX)
_seq = 0

_tls = threading.local()


def _parse_knob(val: str | None) -> tuple[str, str | None]:
    """REPRO_TRACE value -> (mode, path-or-None)."""
    v = (val or "").strip()
    if v.lower() in ("", "0", "off", "false", "no"):
        return "off", None
    if v.lower() in ("1", "log", "on", "true", "yes"):
        return "log", None
    if v.lower() in ("2", "jsonl"):
        return "jsonl", None
    if v.endswith(".jsonl"):
        return "jsonl", v
    return "log", None


def configure(mode: str | None = None, path: str | None = None) -> None:
    """Set the trace mode/path at runtime (tests, benchmarks). With no
    arguments, re-reads ``REPRO_TRACE`` / ``REPRO_TRACE_PATH`` from the
    environment."""
    global _mode, _path, _fh
    with _state_lock:
        if mode is None:
            mode, knob_path = _parse_knob(os.environ.get("REPRO_TRACE"))
            path = path or os.environ.get("REPRO_TRACE_PATH") or knob_path
        if mode not in ("off", "log", "jsonl"):
            raise ValueError(f"unknown trace mode: {mode!r}")
        if _fh is not None:
            _fh.close()
            _fh = None
        _mode = mode
        if path:
            _path = path


def trace_enabled() -> bool:
    return _mode != "off"


def trace_mode() -> str:
    return _mode


def trace_path() -> str:
    return _path


def reset() -> None:
    """Drop buffered spans (tests). Does not change mode/path."""
    global _seq
    with _state_lock:
        _ring.clear()
        _seq = 0


def recent_spans() -> list:
    """Recorded span dicts, oldest first (in-memory ring buffer)."""
    with _state_lock:
        return list(_ring)


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def _trace_stack() -> list:
    st = getattr(_tls, "trace", None)
    if st is None:
        st = _tls.trace = []
    return st


def current_trace() -> str | None:
    """The innermost trace id pinned on this thread (None outside any
    ``trace_scope``)."""
    st = _trace_stack()
    return st[-1] if st else None


class trace_scope:
    """``with trace_scope("req-000042"): ...`` — every span recorded on
    this thread inside the block carries ``trace: "req-000042"``."""

    __slots__ = ("trace_id",)

    def __init__(self, trace_id: str):
        self.trace_id = trace_id

    def __enter__(self):
        _trace_stack().append(self.trace_id)
        return self.trace_id

    def __exit__(self, *exc):
        st = _trace_stack()
        if st and st[-1] == self.trace_id:
            st.pop()
        return False


def _clean_attr(v):
    if isinstance(v, (int, float, str, bool, type(None))):
        return v
    if isinstance(v, (list, tuple)):
        return [_clean_attr(x) for x in v]
    return str(v)


def _emit(rec: dict) -> None:
    global _fh, _seq
    with _state_lock:
        _seq += 1
        rec["seq"] = _seq
        _ring.append(rec)
        if _mode == "jsonl":
            if _fh is None:
                _fh = open(_path, "a", buffering=1)
            _fh.write(json.dumps(rec, sort_keys=True) + "\n")
    if logger.isEnabledFor(logging.DEBUG):
        logger.debug("span %s %.1fus", rec["path"], rec["dur_s"] * 1e6)


def record_span(name: str, dur_s: float, *, t0_s: float | None = None,
                **attrs) -> None:
    """Record a span retroactively (for stages detected after the fact,
    e.g. a compile identified from a jit cache-size delta after the launch
    call returned). Nested under the current thread's open span, if any.
    ``t0_s`` is the start on the ``perf_counter`` clock (defaults to
    now-minus-duration); a ``trace=...`` attribute (or an enclosing
    ``trace_scope``) is hoisted to the record's top-level ``trace``."""
    if _mode == "off":
        return
    st = _stack()
    path = "/".join(st + [name])
    trace = attrs.pop("trace", None) or current_trace()
    rec = {"type": "span", "name": name, "path": path, "dur_s": dur_s,
           "t0_s": (time.perf_counter() - dur_s if t0_s is None
                    else float(t0_s)),
           "tid": threading.get_ident()}
    if trace is not None:
        rec["trace"] = trace
    if attrs:
        rec["attrs"] = {k: _clean_attr(v) for k, v in attrs.items()}
    _emit(rec)


class span:
    """``with span("plan", nq=1024) as sp: ...`` — times the block, tags
    it in the XLA profile, records it per REPRO_TRACE. ``sp.duration`` is
    available after exit; ``sp.set(**attrs)`` adds attributes mid-flight."""

    __slots__ = ("name", "attrs", "duration", "_t0", "_ann", "_path")

    def __init__(self, name: str, **attrs):
        self.name = name
        self.attrs = attrs
        self.duration = 0.0
        self._t0 = 0.0
        self._ann = None
        self._path = name

    def set(self, **attrs):
        self.attrs.update(attrs)

    def __enter__(self):
        st = _stack()
        self._path = "/".join(st + [self.name])
        st.append(self.name)
        # always annotate: profiler visibility must not depend on the
        # host-recording knob, and TraceAnnotation is ~free when no
        # profiler is active
        self._ann = jax.profiler.TraceAnnotation(self.name)
        self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.duration = time.perf_counter() - self._t0
        self._ann.__exit__(*exc)
        st = _stack()
        if st and st[-1] == self.name:
            st.pop()
        if _mode != "off":
            trace = self.attrs.pop("trace", None) or current_trace()
            rec = {"type": "span", "name": self.name, "path": self._path,
                   "dur_s": self.duration, "t0_s": self._t0,
                   "tid": threading.get_ident()}
            if trace is not None:
                rec["trace"] = trace
            if self.attrs:
                rec["attrs"] = {k: _clean_attr(v)
                                for k, v in self.attrs.items()}
            _emit(rec)
        return False


def timeline(trace_id: str, spans: list | None = None) -> list:
    """One request's spans in start-time order: every span whose
    top-level ``trace`` matches, plus batch-granular spans whose
    ``trace_ids`` attribute contains the id. The per-request
    reconstruction the serving acceptance test asserts covers
    admission through resolution."""
    out = []
    for rec in (recent_spans() if spans is None else spans):
        if rec.get("type", "span") != "span":
            continue
        if rec.get("trace") == trace_id:
            out.append(rec)
        else:
            ids = (rec.get("attrs") or {}).get("trace_ids")
            if ids and trace_id in ids:
                out.append(rec)
    out.sort(key=lambda r: (r.get("t0_s", 0.0), r.get("seq", 0)))
    return out


def export_jsonl(path: str | None = None, registry=None) -> str:
    """Dump buffered spans plus the aggregated metric registry as JSONL.

    One ``{"type": "span", ...}`` line per buffered span and one
    ``{"type": "metric", ...}`` line per aggregated metric. Returns the
    path written."""
    from .registry import REGISTRY
    reg = registry if registry is not None else REGISTRY
    out = path or _path
    with open(out, "a", buffering=1) as fh:
        for rec in recent_spans():
            fh.write(json.dumps(rec, sort_keys=True) + "\n")
        reg.export_metrics_jsonl(fh)
    return out


# pick up the env knob at import so `REPRO_TRACE=1 pytest` just works
configure()
