"""Unified metrics registry (DESIGN.md section 9).

One process-wide registry replaces the scatter of ad-hoc ``stats()``
dicts: components (executor, session, sharded_session, serve, ...) own a
:class:`MetricSet` of named counters, gauges, and latency histograms, and
the registry aggregates across every live instance — so
``repro.obs.summary()`` is the one place the caching/sync/latency story of
a whole process can be read, and ``repro.obs.export_jsonl()`` emits the
same numbers machine-readably under the schema the benchmark gate
(``scripts/check_bench.py``) consumes.

Metric kinds and their cross-instance merge semantics:

* **counter** — monotonic float/int total; merged by SUM.
* **gauge**   — last-written value; merged by most-recent write.
* **histogram** — streaming latency/size distribution: exact count / sum /
  min / max plus a bounded reservoir of recent samples from which p50 /
  p95 / p99 are computed on demand; merged by combining the exact moments
  and concatenating (capped) reservoirs.

The registry keeps strong references to a bounded number of recent
MetricSets; older sets are *folded* into a retired aggregate on eviction,
so totals survive instance churn (tests build hundreds of executors)
without pinning instances or growing without bound.
"""
from __future__ import annotations

import collections
import json
import threading
import time

import numpy as np

_RESERVOIR_MAX = 2048
_LIVE_SETS_MAX = 512

_PERCENTILES = (50.0, 95.0, 99.0)


class Counter:
    """Monotonic total. ``inc`` returns the new value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> float:
        self.value += v
        return self.value

    def snapshot(self) -> dict:
        return {"kind": "counter", "value": self.value}


class Gauge:
    """Last-written value (cache sizes, boosts, current occupancy)."""

    __slots__ = ("value", "tick")

    def __init__(self):
        self.value = 0.0
        self.tick = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)
        self.tick = time.monotonic()

    def snapshot(self) -> dict:
        return {"kind": "gauge", "value": self.value, "tick": self.tick}


class Histogram:
    """Streaming distribution: exact moments + bounded sample reservoir.

    ``percentiles()`` (p50/p95/p99 by default) are computed from the
    reservoir of the most recent ``_RESERVOIR_MAX`` samples — exact for
    short runs, recency-weighted for long ones, which is the right bias
    for latency monitoring.
    """

    __slots__ = ("count", "total", "vmin", "vmax", "samples")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.samples: collections.deque = collections.deque(
            maxlen=_RESERVOIR_MAX)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        self.samples.append(v)

    def percentiles(self, qs=_PERCENTILES) -> dict:
        if not self.samples:
            return {f"p{q:g}": 0.0 for q in qs}
        arr = np.asarray(self.samples, np.float64)
        vals = np.percentile(arr, qs)
        return {f"p{q:g}": float(v) for q, v in zip(qs, vals)}

    def snapshot(self) -> dict:
        out = {"kind": "histogram", "count": self.count, "sum": self.total,
               "min": self.vmin if self.count else 0.0,
               "max": self.vmax if self.count else 0.0}
        out.update(self.percentiles())
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricSet:
    """One component instance's named metrics (owned by the instance,
    registered with the process registry for aggregation).

    The accessors are get-or-create, so recording a metric is one line at
    the call site: ``ms.count("queries")``, ``ms.observe("query_s", dt)``,
    ``ms.gauge("cache_entries", n)``.
    """

    __slots__ = ("component", "_metrics", "_lock")

    def __init__(self, component: str):
        self.component = component
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _get(self, kind: str, name: str):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(name, _KINDS[kind]())
        return m

    # -- recording ----------------------------------------------------------

    def count(self, name: str, v: float = 1.0) -> float:
        return self._get("counter", name).inc(v)

    def gauge(self, name: str, v: float) -> None:
        self._get("gauge", name).set(v)

    def observe(self, name: str, v: float) -> None:
        self._get("histogram", name).observe(v)

    # -- reading ------------------------------------------------------------

    def counter_value(self, name: str) -> float:
        m = self._metrics.get(name)
        return float(m.value) if isinstance(m, Counter) else 0.0

    def counters(self) -> dict:
        """{name: int-or-float total} over the counter metrics only —
        the drop-in replacement for the legacy ``collections.Counter``
        totals the old ``stats()`` dicts were built from."""
        out = {}
        for name, m in self._metrics.items():
            if isinstance(m, Counter):
                v = m.value
                out[name] = int(v) if float(v).is_integer() else v
        return out

    def snapshot(self) -> dict:
        return {name: m.snapshot() for name, m in
                sorted(self._metrics.items())}


def _merge(into: dict, frm: dict) -> None:
    """Merge one snapshot dict into an aggregate (per-kind semantics)."""
    for name, snap in frm.items():
        cur = into.get(name)
        if cur is None:
            into[name] = dict(snap)
            if snap["kind"] == "histogram":
                into[name] = dict(snap)
            continue
        kind = snap["kind"]
        if kind == "counter":
            cur["value"] += snap["value"]
        elif kind == "gauge":
            if snap.get("tick", 0.0) >= cur.get("tick", 0.0):
                cur.update(snap)
        elif kind == "histogram":
            n0, n1 = cur["count"], snap["count"]
            if n1 == 0:
                continue
            if n0 == 0:
                cur.update(snap)
                continue
            cur["count"] = n0 + n1
            cur["sum"] += snap["sum"]
            cur["min"] = min(cur["min"], snap["min"])
            cur["max"] = max(cur["max"], snap["max"])
            # percentile fields: count-weighted blend — approximate, but
            # the registry aggregate is for the summary table; per-set
            # snapshots keep the exact reservoir quantiles
            for q in _PERCENTILES:
                key = f"p{q:g}"
                cur[key] = (cur[key] * n0 + snap[key] * n1) / (n0 + n1)


class Registry:
    """Process-wide aggregation point over every component MetricSet."""

    def __init__(self):
        self._lock = threading.Lock()
        self._live: collections.OrderedDict = collections.OrderedDict()
        self._retired: dict = {}            # component -> merged snapshot
        self._seq = 0

    # -- membership ---------------------------------------------------------

    def metric_set(self, component: str) -> MetricSet:
        """Create and register a new instance-scoped MetricSet."""
        ms = MetricSet(component)
        with self._lock:
            self._seq += 1
            self._live[self._seq] = ms
            while len(self._live) > _LIVE_SETS_MAX:
                _k, old = self._live.popitem(last=False)
                _merge(self._retired.setdefault(old.component, {}),
                       old.snapshot())
        return ms

    def reset(self) -> None:
        with self._lock:
            self._live.clear()
            self._retired.clear()

    # -- reading ------------------------------------------------------------

    def aggregate(self) -> dict:
        """{component: {metric: merged snapshot}} over live + retired."""
        out: dict = {}
        with self._lock:
            for comp, snap in self._retired.items():
                _merge(out.setdefault(comp, {}), snap)
            for ms in self._live.values():
                _merge(out.setdefault(ms.component, {}), ms.snapshot())
        return out

    def metrics_dict(self) -> dict:
        """The unified metric schema: a flat list of metric records,
        each ``{component, name, kind, ...values}`` — the shape both
        ``export_jsonl`` and the benchmark tooling consume."""
        rows = []
        for comp, metrics in sorted(self.aggregate().items()):
            for name, snap in sorted(metrics.items()):
                rows.append({"component": comp, "name": name, **snap})
        return {"schema": "repro.obs/v1", "metrics": rows}

    def summary(self) -> str:
        """Human-readable table of the unified registry (the replacement
        for eyeballing N different stats() dicts)."""
        agg = self.aggregate()
        lines = ["# repro.obs summary",
                 f"# {'component':<18}{'metric':<34}{'value':>14}"
                 f"{'p50':>10}{'p95':>10}{'p99':>10}{'n':>8}"]
        if not agg:
            lines.append("# (no metrics recorded)")
        for comp, metrics in sorted(agg.items()):
            for name, snap in sorted(metrics.items()):
                if snap["kind"] == "histogram":
                    scale, unit = ((1e6, "_us") if name.endswith("_s")
                                   else (1.0, ""))
                    disp = name[:-2] + unit if unit else name
                    lines.append(
                        f"# {comp:<18}{disp:<34}{'':>14}"
                        f"{snap['p50'] * scale:>10.1f}"
                        f"{snap['p95'] * scale:>10.1f}"
                        f"{snap['p99'] * scale:>10.1f}"
                        f"{snap['count']:>8d}")
                else:
                    v = snap["value"]
                    vs = f"{v:.0f}" if float(v).is_integer() else f"{v:.4g}"
                    lines.append(f"# {comp:<18}{name:<34}{vs:>14}"
                                 f"{'':>10}{'':>10}{'':>10}{'':>8}")
        return "\n".join(lines)

    def export_metrics_jsonl(self, fh) -> int:
        """Write one JSONL line per aggregated metric; returns the line
        count."""
        payload = self.metrics_dict()
        n = 0
        for row in payload["metrics"]:
            fh.write(json.dumps({"type": "metric", **row},
                                sort_keys=True) + "\n")
            n += 1
        return n


REGISTRY = Registry()
