"""Layer library: init + apply for every block the 10 archs need.

Functional style: ``init_*`` returns a param dict; ``*_fwd`` applies it.
All matmuls run in ``cfg`` compute dtype with f32 accumulation where it
matters (norms, softmax, router, recurrences); logits are f32.

Sharding: activations are annotated through the ``shard`` callable
(name -> constraint); a no-op by default so smoke tests run meshless.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any
NO_SHARD = lambda x, name: x


def _dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> PyTree:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(x: Array, p: PyTree, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> PyTree:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(x: Array, p: PyTree, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(d_rot: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, jnp.float32) / d_rot))


def apply_rope(x: Array, pos: Array, theta: float) -> Array:
    """x [B,S,H,D] (D even, fully rotary), pos [B,S] int -> rotated x."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                          # [D/2]
    ang = pos[..., None].astype(jnp.float32) * freqs      # [B,S,D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, pos3: Array, theta: float,
                sections: tuple[int, int, int]) -> Array:
    """Qwen2-VL M-RoPE: the head dim's frequency bands are split into
    (temporal, height, width) sections, each rotated by its own position
    stream. x [B,S,H,D], pos3 [B,S,3]."""
    d = x.shape[-1]
    half = d // 2
    freqs = rope_freqs(d, theta)                          # [half]
    # section s covers freqs[off:off+sections[s]]
    sec = jnp.zeros((half,), jnp.int32)
    off = 0
    for i, s in enumerate(sections):
        sec = sec.at[off:off + s].set(i)
        off += s
    pos_per_freq = jnp.take_along_axis(
        pos3.astype(jnp.float32),                         # [B,S,3]
        jnp.broadcast_to(sec[None, None, :], pos3.shape[:2] + (half,)),
        axis=-1)                                          # [B,S,half]
    ang = pos_per_freq * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA / MQA / MHA), optional sliding window, KV cache
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype) -> PyTree:
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h, hd), dtype=dtype),
        "wk": _dense_init(ks[1], (d, hk, hd), dtype=dtype),
        "wv": _dense_init(ks[2], (d, hk, hd), dtype=dtype),
        "wo": _dense_init(ks[3], (h, hd, d), scale=1.0 / math.sqrt(h * hd),
                          dtype=dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((hk, hd), dtype)
        p["bv"] = jnp.zeros((hk, hd), dtype)
    return p


def _sdpa(q: Array, k: Array, v: Array, *, causal: bool,
          window: int | None, q_offset: Array | int = 0,
          kpos: Array | None = None, shard=NO_SHARD) -> Array:
    """q [B,Sq,H,D], k/v [B,Sk,Hk,D] -> [B,Sq,H,D]. GQA by head grouping.

    When the kv-head count does not divide the tensor-parallel axis but the
    q-head count does (e.g. kv=8 under model=16), kv heads are REPLICATED to
    H (Megatron-style) so attention shards fully on q heads — otherwise the
    score tensor replicates across the model axis and attention compute
    blows up by the axis size (EXPERIMENTS.md section Perf, iteration 1).

    ``q_offset`` positions query i at absolute position q_offset+i for
    causal/window masking against the absolute-indexed k axis.
    """
    b, sq, h, d = q.shape
    sk, hk = k.shape[1], k.shape[2]
    dv = v.shape[-1]           # MLA: value head dim != qk head dim
    g = h // hk
    msize = getattr(shard, "model_size", 1)
    expand = g > 1 and (hk % msize != 0) and (h % msize == 0)

    qpos = jnp.arange(sq)[:, None] + q_offset
    if kpos is None:
        kp = jnp.arange(sk)[None, :]
        mask = jnp.ones((sq, sk), bool)
    else:
        # explicit absolute key positions (ring-buffer caches); negative
        # entries mark unwritten slots
        kp = kpos[None, :]
        mask = kp >= 0
    if causal:
        mask &= kp <= qpos
    if window is not None:
        mask &= kp > qpos - window

    if expand:
        ke = jnp.repeat(k, g, axis=2)                       # [B,Sk,H,D]
        ve = jnp.repeat(v, g, axis=2)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, ke,
                            preferred_element_type=jnp.float32)
        logits = shard(logits, "attn_logits4") / math.sqrt(d)
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, ve)
        return out
    qg = q.reshape(b, sq, hk, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    logits = shard(logits, "attn_logits")
    logits = logits / math.sqrt(d)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, dv)


def attention_fwd(p: PyTree, x: Array, cfg, *, pos: Array,
                  cache: PyTree | None = None, causal: bool = True,
                  window: int | None = None, shard=NO_SHARD
                  ) -> tuple[Array, PyTree | None]:
    """Returns (out [B,S,d], new_cache). ``cache`` = dict(k, v, length) with
    k/v [B, S_max, Hk, D]; decode appends at ``length``."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = shard(q, "act_heads")

    if cfg.pos == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    elif cfg.pos == "mrope":
        hd = cfg.head_dim
        sections = (hd // 2 - 2 * (hd // 2 // 3), hd // 2 // 3, hd // 2 // 3)
        q = apply_mrope(q, pos, cfg.rope_theta, sections)
        k = apply_mrope(k, pos, cfg.rope_theta, sections)

    if cache is None:
        out = _sdpa(q, k, v, causal=causal, window=window, shard=shard)
        new_cache = None
    elif "pos" in cache:
        # ring-buffer cache (sliding-window layers): write at
        # length % s_max, track absolute key positions for the mask —
        # cache memory stays O(window), the sub-quadratic decode claim
        length = cache["length"]
        s_max = cache["k"].shape[1]
        slot = length % s_max
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(
            cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(
            cache["v"].dtype), (0, slot, 0, 0))
        new_pos = jnp.broadcast_to(
            jnp.arange(q.shape[1], dtype=jnp.int32)[None] + length,
            (cache["pos"].shape[0], q.shape[1]))
        cp = jax.lax.dynamic_update_slice(cache["pos"], new_pos, (0, slot))
        out = _sdpa(q, ck, cv, causal=True, window=window,
                    q_offset=length, kpos=cp[0], shard=shard)
        new_cache = {"k": ck, "v": cv, "pos": cp,
                     "length": length + q.shape[1]}
    else:
        length = cache["length"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(
            cache["k"].dtype), (0, length, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(
            cache["v"].dtype), (0, length, 0, 0))
        # causal mask with q_offset both enforces causality and excludes
        # unwritten cache rows (kpos > length + Sq - 1)
        out = _sdpa(q, ck, cv, causal=True, window=window,
                    q_offset=length, shard=shard)
        new_cache = {"k": ck, "v": cv, "length": length + q.shape[1]}
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard(o, "act_resid"), new_cache


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2/V3, MiniCPM3)
# ---------------------------------------------------------------------------

def init_mla(key, cfg, dtype) -> PyTree:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "q_a": _dense_init(ks[0], (d, m.q_rank), dtype=dtype),
        "q_norm": init_rmsnorm(m.q_rank, dtype),
        "q_b": _dense_init(ks[1], (m.q_rank, h, m.d_nope + m.d_rope),
                           dtype=dtype),
        "kv_a": _dense_init(ks[2], (d, m.kv_rank + m.d_rope), dtype=dtype),
        "kv_norm": init_rmsnorm(m.kv_rank, dtype),
        "kv_b": _dense_init(ks[3], (m.kv_rank, h, m.d_nope + m.d_v),
                            dtype=dtype),
        "wo": _dense_init(ks[4], (h, m.d_v, d),
                          scale=1.0 / math.sqrt(h * m.d_v), dtype=dtype),
    }


def _mla_absorbed_decode(p: PyTree, q_nope, q_rope, latent, k_rope,
                         length, m, shard=NO_SHARD):
    """Absorbed MLA decode (DeepSeek-V2 section 2.1.3 trick).

    The naive decode expands the latent cache through kv_b to full K/V every
    step — O(S * H * (d_nope + d_v)) work and traffic. Absorbing kv_b's key
    half into the query and its value half into the output keeps attention
    entirely in the kv_rank-dim latent space: O(S * kv_rank) per head-step.
    Recorded as EXPERIMENTS.md Perf iteration 2 (deepseek/minicpm3 decode).
    """
    kv_b_k = p["kv_b"][..., : m.d_nope]            # [r, H, d_nope]
    kv_b_v = p["kv_b"][..., m.d_nope:]             # [r, H, d_v]
    # query into latent space: [B,1,H,r]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, kv_b_k)
    scores = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                        latent.astype(jnp.float32))
    scores += jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                         k_rope[:, :, 0].astype(jnp.float32))
    scores = scores / math.sqrt(m.d_nope + m.d_rope)
    s_max = latent.shape[1]
    valid = jnp.arange(s_max)[None, None, None, :] <= length
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", probs.astype(latent.dtype), latent)
    out = jnp.einsum("bshr,rhv->bshv", o_lat, kv_b_v)  # [B,1,H,d_v]
    return out


def mla_fwd(p: PyTree, x: Array, cfg, *, pos: Array,
            cache: PyTree | None = None, shard=NO_SHARD
            ) -> tuple[Array, PyTree | None]:
    """MLA forward. The decode cache stores only the compressed latent
    (kv_rank) + shared rope key (d_rope) per token — the memory win that
    makes MLA's long-context decode cheap."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads

    q = jnp.einsum("bsd,dr->bsr", x, p["q_a"])
    q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q, p["q_b"])
    q_nope, q_rope = q[..., : m.d_nope], q[..., m.d_nope:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], -1)
    q = shard(q, "act_heads")

    kv = jnp.einsum("bsd,dr->bsr", x, p["kv_a"])
    latent, k_rope = kv[..., : m.kv_rank], kv[..., m.kv_rank:]
    latent = rmsnorm(latent, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)

    if cache is not None:
        length = cache["length"]
        latent_c = jax.lax.dynamic_update_slice(
            cache["latent"], latent.astype(cache["latent"].dtype),
            (0, length, 0))
        k_rope_c = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            (0, length, 0, 0))
        new_cache = {"latent": latent_c, "k_rope": k_rope_c,
                     "length": length + s}
        if s == 1:
            # absorbed decode: never expands the latent cache
            out = _mla_absorbed_decode(p, q_nope, q_rope, latent_c,
                                       k_rope_c, length, m, shard)
            o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
            return shard(o, "act_resid"), new_cache
        latent, k_rope, q_offset = latent_c, k_rope_c, length
    else:
        new_cache = None
        q_offset = 0

    kv_full = jnp.einsum("bsr,rhk->bshk", latent, p["kv_b"])
    k_nope, v = kv_full[..., : m.d_nope], kv_full[..., m.d_nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (m.d_rope,))],
        -1)
    out = _sdpa(q, k, v, causal=True, window=None, q_offset=q_offset,
                shard=shard)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard(o, "act_resid"), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key, d: int, ff: int, dtype) -> PyTree:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, ff), dtype=dtype),
        "w_up": _dense_init(ks[1], (d, ff), dtype=dtype),
        "w_down": _dense_init(ks[2], (ff, d), dtype=dtype),
    }


def swiglu_fwd(p: PyTree, x: Array, shard=NO_SHARD) -> Array:
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = shard(jax.nn.silu(g) * u, "act_ffn")
    return shard(jnp.einsum("bsf,fd->bsd", h, p["w_down"]), "act_resid")


def init_gelu_mlp(key, d: int, ff: int, dtype) -> PyTree:
    ks = jax.random.split(key, 2)
    return {
        "w1": _dense_init(ks[0], (d, ff), dtype=dtype),
        "b1": jnp.zeros((ff,), dtype),
        "w2": _dense_init(ks[1], (ff, d), dtype=dtype),
        "b2": jnp.zeros((d,), dtype),
    }


def gelu_mlp_fwd(p: PyTree, x: Array, shard=NO_SHARD) -> Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w1"]) + p["b1"]
    h = shard(jax.nn.gelu(h), "act_ffn")
    return shard(jnp.einsum("bsf,fd->bsd", h, p["w2"]) + p["b2"],
                 "act_resid")


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k routing, sorted capacity dispatch)
# ---------------------------------------------------------------------------

def init_moe(key, cfg, dtype) -> PyTree:
    mo = cfg.moe
    d = cfg.d_model
    ff = mo.d_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, mo.n_experts), scale=0.02,
                              dtype=jnp.float32),
        "w_gate": _dense_init(ks[1], (mo.n_experts, d, ff), dtype=dtype),
        "w_up": _dense_init(ks[2], (mo.n_experts, d, ff), dtype=dtype),
        "w_down": _dense_init(ks[3], (mo.n_experts, ff, d), dtype=dtype),
    }
    if mo.router_aux_free:
        p["router_bias"] = jnp.zeros((mo.n_experts,), jnp.float32)
    if mo.n_shared:
        p["shared"] = init_swiglu(ks[4], d, ff * mo.n_shared, dtype)
    return p


def moe_fwd(p: PyTree, x: Array, cfg, shard=NO_SHARD) -> Array:
    """Top-k MoE with *sorted* capacity dispatch.

    Tokens are sorted by routed expert before the expert GEMMs — the same
    coherence transformation as the paper's section-4 query scheduling
    (sort work items so adjacent lanes take the same path), applied to
    expert-route divergence instead of ray divergence (DESIGN.md section 4).
    """
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    if "router_bias" in p:
        # DeepSeek-V3 aux-free balancing: bias shifts selection only
        sel_logits = logits + p["router_bias"]
    else:
        sel_logits = logits
    gates, experts = jax.lax.top_k(sel_logits, mo.top_k)      # [t, k]
    probs = jax.nn.softmax(
        jnp.take_along_axis(logits, experts, axis=1), axis=-1)

    # ---- sorted dispatch (coherence sort) ----
    flat_e = experts.reshape(-1)                              # [t*k]
    order = jnp.argsort(flat_e)                               # sort by expert
    sorted_e = flat_e[order]
    # rank within expert group
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(t * mo.top_k) - first
    cap = int(math.ceil(t * mo.top_k / mo.n_experts * mo.capacity_factor))
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, mo.n_experts * cap)
    token_of = order // mo.top_k
    # gather tokens into [E, cap, d]
    buf = jnp.zeros((mo.n_experts * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[token_of], mode="drop")
    buf = shard(buf[:-1].reshape(mo.n_experts, cap, d), "moe_dispatch")

    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = shard(jax.nn.silu(g) * u, "moe_ffn")
    eo = jnp.einsum("ecf,efd->ecd", h, p["w_down"])           # [E, cap, d]

    # scatter back with router weights
    w = probs.reshape(-1)[order]                              # [t*k]
    contrib = eo.reshape(-1, d)                               # [E*cap, d]
    out = jnp.zeros((t, d), jnp.float32)
    safe_slot = jnp.clip(slot, 0, mo.n_experts * cap - 1)
    src = jnp.where(keep[:, None], contrib[safe_slot]
                    .astype(jnp.float32) * w[:, None], 0.0)
    out = out.at[token_of].add(src)
    out = out.astype(x.dtype)

    if "shared" in p:
        out = out + swiglu_fwd(p["shared"], xf[None], shard)[0]
    return shard(out.reshape(b, s, d), "act_resid")


def moe_aux_loss(p: PyTree, x: Array, cfg) -> Array:
    """Load-balancing auxiliary loss (Switch-style); returns scalar f32."""
    mo = cfg.moe
    t = x.shape[0] * x.shape[1]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1).reshape(t, mo.n_experts)
    _, experts = jax.lax.top_k(logits.reshape(t, -1), mo.top_k)
    counts = jnp.zeros((mo.n_experts,), jnp.float32).at[
        experts.reshape(-1)].add(1.0)
    frac_tokens = counts / (t * mo.top_k)
    frac_probs = probs.mean(0)
    return mo.n_experts * jnp.sum(frac_tokens * frac_probs)


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------

def init_rglru_block(key, cfg, dtype) -> PyTree:
    d = cfg.d_model
    dr = d  # lru width = d_model in RecurrentGemma-2B
    ks = jax.random.split(key, 7)
    c = 8.0
    # a = sigmoid(lam) ** c initialised so a in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log((u ** (1 / c)) / (1 - u ** (1 / c)))
    return {
        "w_x": _dense_init(ks[1], (d, dr), dtype=dtype),      # linear branch
        "w_y": _dense_init(ks[2], (d, dr), dtype=dtype),      # gate branch
        "conv_w": _dense_init(ks[3], (4, dr), scale=0.5, dtype=dtype),
        "lam": lam,                                           # f32
        "w_a": _dense_init(ks[4], (dr, dr), scale=0.02, dtype=dtype),
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_i": _dense_init(ks[5], (dr, dr), scale=0.02, dtype=dtype),
        "b_i": jnp.zeros((dr,), jnp.float32),
        "w_out": _dense_init(ks[6], (dr, d), dtype=dtype),
    }


def _rglru_scan(xt: Array, a_t: Array, h0: Array) -> tuple[Array, Array]:
    """Linear recurrence h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * x_t via
    associative scan over the sequence axis. xt/a_t [B,S,D] f32."""
    gated = jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 1e-12)) * xt

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_acc, h = jax.lax.associative_scan(combine, (a_t, gated), axis=1)
    h = h + a_acc * h0[:, None, :]
    return h, h[:, -1, :]


def rglru_block_fwd(p: PyTree, x: Array, cfg, *,
                    cache: PyTree | None = None, shard=NO_SHARD
                    ) -> tuple[Array, PyTree | None]:
    """Griffin recurrent block: (conv1d -> RG-LRU) branch gated by GeLU
    branch. ``cache`` = dict(h [B,D], conv [B,3,D]) for decode."""
    b, s, d = x.shape
    xb = jnp.einsum("bsd,de->bse", x, p["w_x"])
    yb = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_y"]))

    # depthwise causal conv, kernel 4
    if cache is None:
        prev = jnp.zeros((b, 3, xb.shape[-1]), xb.dtype)
    else:
        prev = cache["conv"].astype(xb.dtype)
    xpad = jnp.concatenate([prev, xb], axis=1)
    conv = sum(xpad[:, i : i + s, :] * p["conv_w"][i] for i in range(4))
    new_conv = xpad[:, -3:, :]

    cf = conv.astype(jnp.float32)
    r = jax.nn.sigmoid(cf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(cf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -8.0 * r * jax.nn.softplus(p["lam"])          # log a_t
    a_t = jnp.exp(log_a)
    gated_x = i * cf
    h0 = (jnp.zeros((b, xb.shape[-1]), jnp.float32) if cache is None
          else cache["h"].astype(jnp.float32))
    h, h_last = _rglru_scan(gated_x, a_t, h0)
    h = shard(h.astype(x.dtype), "act_ffn")

    out = jnp.einsum("bse,ed->bsd", h * yb, p["w_out"])
    new_cache = None if cache is None else {"h": h_last, "conv": new_conv}
    return shard(out, "act_resid"), new_cache


# ---------------------------------------------------------------------------
# RWKV-6 (Finch): data-dependent decay time-mix + channel-mix
# ---------------------------------------------------------------------------

def init_rwkv6(key, cfg, dtype) -> PyTree:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    n_h = d // hd
    ks = jax.random.split(key, 12)
    lora = 64
    return {
        "maa": 0.5 * jnp.ones((5, d), jnp.float32),          # r,k,v,w,g mix
        "w0": jnp.full((d,), -6.0, jnp.float32),             # decay base
        "w1": _dense_init(ks[0], (d, lora), scale=0.02, dtype=jnp.float32),
        "w2": _dense_init(ks[1], (lora, d), scale=0.02, dtype=jnp.float32),
        "u": jnp.zeros((n_h, hd), jnp.float32),              # bonus
        "wr": _dense_init(ks[2], (d, d), dtype=dtype),
        "wk": _dense_init(ks[3], (d, d), dtype=dtype),
        "wv": _dense_init(ks[4], (d, d), dtype=dtype),
        "wg": _dense_init(ks[5], (d, d), dtype=dtype),
        "wo": _dense_init(ks[6], (d, d), dtype=dtype),
        "ln_x": init_layernorm(d, jnp.float32),              # group-norm-ish
    }


import os as _os

# chunked-parallel RWKV6 (EXPERIMENTS.md Perf iteration 4): 0 = sequential
# lax.scan reference; >0 = chunk length of the parallel form
RWKV_CHUNK = int(_os.environ.get("REPRO_RWKV_CHUNK", "16"))
_LOG_DECAY_CLAMP = 5.0   # per-step |log w| cap: keeps all chunk exponent
                         # differences within f32 range (DESIGN/EXPERIMENTS)


def _rwkv_scan_core(rf, kf, vf, wf, u, state0):
    """Reference recurrence (sequential scan over time).
    S_t = diag(w_t) S_{t-1} + k_t^T v_t ; out_t = r_t (S_{t-1} + u k^T v).
    rf/kf/vf/wf [B,S,H,hd] f32; state0 [B,H,hd,hd] f32."""

    def step(state, ins):
        r_t, k_t, v_t, w_t = ins                             # [B,H,hd]
        kv = k_t[..., :, None] * v_t[..., None, :]           # [B,H,hd,hd]
        out = jnp.einsum("bhi,bhij->bhj", r_t, state + u[..., None] * kv)
        state = w_t[..., None] * state + kv
        return state, out

    ins = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    state_last, outs = jax.lax.scan(step, state0, ins)
    return jnp.moveaxis(outs, 0, 1), state_last


def _rwkv_chunked_core(rf, kf, vf, wf, u, state0, chunk: int):
    """Chunked-parallel form: identical math, O(S/chunk) state traffic.

    Within a chunk, out_t = r_t diag(A_{t-1}) S_0
                          + sum_{i<t} r_t diag(A_{t-1}/A_i) k_i^T v_i
                          + (r_t . u k_t) v_t
    with A_t = prod_{j<=t} w_j. All three terms are matmuls (MXU) over the
    chunk; the carried state materializes once per chunk instead of once
    per token — the sequential scan's dominant HBM traffic (state
    read+write every step) drops by ~chunk x. Exponent differences stay in
    f32 range because per-step |log w| <= _LOG_DECAY_CLAMP and chunks are
    short (16 * 5 = 80 < log(f32max) ~ 88.7).
    """
    b, s, h, hd = rf.shape
    pad = (-s) % chunk
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        rf, kf, vf = z(rf), z(kf), z(vf)
        wf = jnp.pad(wf, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
    n_c = (s + pad) // chunk
    resh = lambda t: t.reshape(b, n_c, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, wc = resh(rf), resh(kf), resh(vf), resh(wf)  # [N,B,C,H,hd]

    lw = jnp.log(jnp.maximum(wc, 1e-38))                     # <= 0
    lA = jnp.cumsum(lw, axis=2)                              # inclusive
    lA_ex = lA - lw                                          # exclusive
    mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])

    def one_chunk(state, ins):
        r, k, v, la, la_ex = ins                             # [B,C,H,hd]
        la_c = la[:, -1:, :, :]                              # total decay
        rr = r * jnp.exp(la_ex)                              # <= |r|, safe
        kk_neg = k * jnp.exp(-la)                            # bounded by clamp
        # inter-chunk: decayed initial state
        out = jnp.einsum("bchk,bhkv->bchv", rr, state)
        # intra-chunk (strictly causal)
        scores = jnp.einsum("bthk,bihk->bhti", rr, kk_neg)
        scores = jnp.where(mask[None, None], scores, 0.0)
        out += jnp.einsum("bhti,bihv->bthv", scores, v)
        # diagonal bonus term
        bonus = jnp.einsum("bchk,bchk->bch", r, u[None, None] * k)
        out += bonus[..., None] * v
        # state to next chunk
        k_dec = k * jnp.exp(la_c - la)
        state = state * jnp.exp(la_c[:, 0])[..., None] + \
            jnp.einsum("bihk,bihv->bhkv", k_dec, v)
        return state, out

    state_last, outs = jax.lax.scan(one_chunk, state0,
                                    (rc, kc, vc, lA, lA_ex))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s + pad, h, hd)
    return out[:, :s], state_last


def rwkv6_timemix_fwd(p: PyTree, x: Array, cfg, *,
                      cache: PyTree | None = None, shard=NO_SHARD
                      ) -> tuple[Array, PyTree | None]:
    """RWKV-6 time mix. State S [B, H, hd, hd]; recurrence
    S_t = diag(w_t) S_{t-1} + k_t^T v_t ; out_t = r_t (S_{t-1} + u k_t^T v_t).
    Training/prefill use the chunked-parallel core when RWKV_CHUNK > 0
    (identical math, validated in tests); decode uses the single-step form.
    """
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    n_h = d // hd

    if cache is None:
        x_prev = jnp.zeros((b, 1, d), x.dtype)
        state0 = jnp.zeros((b, n_h, hd, hd), jnp.float32)
    else:
        x_prev = cache["x_prev"][:, None, :].astype(x.dtype)
        state0 = cache["state"]
    xs = jnp.concatenate([x_prev, x[:, :-1, :]], axis=1)     # token shift
    diff = xs - x

    def mix(i):
        return x + diff * p["maa"][i].astype(x.dtype)

    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(b, s, n_h, hd)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(b, s, n_h, hd)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"]).reshape(b, s, n_h, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"]))
    wf = xw.astype(jnp.float32)
    w = p["w0"] + jnp.tanh(wf @ p["w1"]) @ p["w2"]           # [B,S,d]
    w = jnp.exp(-jnp.clip(jnp.exp(w), 0.0, _LOG_DECAY_CLAMP))
    w = w.reshape(b, s, n_h, hd)                             # decay in (0,1)

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    u = p["u"]

    if RWKV_CHUNK > 0 and s > 1:
        out, state_last = _rwkv_chunked_core(
            rf, kf, vf, w.astype(jnp.float32), u, state0, RWKV_CHUNK)
    else:
        out, state_last = _rwkv_scan_core(
            rf, kf, vf, w.astype(jnp.float32), u, state0)
    out = out.reshape(b, s, d)                               # [B,S,d]
    out = layernorm(out, p["ln_x"], 1e-5).astype(x.dtype) * g.astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", out, p["wo"])
    new_cache = None
    if cache is not None:
        new_cache = {"x_prev": x[:, -1, :], "state": state_last}
    return shard(out, "act_resid"), new_cache


def init_rwkv6_channelmix(key, cfg, dtype) -> PyTree:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "maa_k": 0.5 * jnp.ones((d,), jnp.float32),
        "maa_r": 0.5 * jnp.ones((d,), jnp.float32),
        "wk": _dense_init(ks[0], (d, ff), dtype=dtype),
        "wv": _dense_init(ks[1], (ff, d), dtype=dtype),
        "wr": _dense_init(ks[2], (d, d), dtype=dtype),
    }


def rwkv6_channelmix_fwd(p: PyTree, x: Array, cfg, *,
                         cache: PyTree | None = None, shard=NO_SHARD
                         ) -> tuple[Array, PyTree | None]:
    b, s, d = x.shape
    if cache is None:
        x_prev = jnp.zeros((b, 1, d), x.dtype)
    else:
        x_prev = cache["x_prev"][:, None, :].astype(x.dtype)
    xs = jnp.concatenate([x_prev, x[:, :-1, :]], axis=1)
    diff = xs - x
    xk = x + diff * p["maa_k"].astype(x.dtype)
    xr = x + diff * p["maa_r"].astype(x.dtype)
    kk = jnp.einsum("bsd,df->bsf", xk, p["wk"])
    h = shard(jnp.square(jax.nn.relu(kk)), "act_ffn")
    kv = jnp.einsum("bsf,fd->bsd", h, p["wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]))
    new_cache = None if cache is None else {"x_prev": x[:, -1, :]}
    return shard(rr * kv, "act_resid"), new_cache
