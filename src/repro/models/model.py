"""Model assembly: config -> params / train forward / decode step.

HLO-size discipline: layers are executed as ``lax.scan`` over *periods* of
the config's layer pattern (per-period params stacked on a leading axis),
so the lowered HLO contains one trace per distinct layer kind rather than
one per layer. A ``dense_prefix`` (DeepSeek's first dense layers) and any
tail layers that do not fill a whole period get their own groups.

Memory discipline: the period body is wrapped in ``jax.checkpoint`` (layer-
boundary remat) and the cross-entropy is computed in sequence chunks with
the vocab axis sharded (chunked_ce_loss) so full [B,S,V] logits are never
materialized.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .config import ArchConfig

Array = jax.Array
PyTree = Any
NO_SHARD = L.NO_SHARD


# ---------------------------------------------------------------------------
# per-layer init/apply dispatch
# ---------------------------------------------------------------------------

def _layer_uses_moe(cfg: ArchConfig, kind: str) -> bool:
    return cfg.moe is not None and kind == "attn"


def _norm(x: Array, p: PyTree, eps: float) -> Array:
    """Dispatch RMSNorm vs LayerNorm on param structure."""
    return L.layernorm(x, p, eps) if "bias" in p else L.rmsnorm(x, p, eps)


def _init_block_norm(cfg: ArchConfig, dtype) -> PyTree:
    return (L.init_layernorm(cfg.d_model, dtype) if cfg.family == "audio"
            else L.init_rmsnorm(cfg.d_model, dtype))


def _ffn_fwd(p: PyTree, x: Array, cfg: ArchConfig, shard) -> Array:
    if "router" in p:
        return L.moe_fwd(p, x, cfg, shard=shard)
    if "w1" in p:
        return L.gelu_mlp_fwd(p, x, shard=shard)
    return L.swiglu_fwd(p, x, shard=shard)


def init_layer(key, cfg: ArchConfig, kind: str, dtype) -> PyTree:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in ("attn", "attn_dense", "local_attn"):
        p = {"ln1": _init_block_norm(cfg, dtype),
             "ln2": _init_block_norm(cfg, dtype)}
        if cfg.mla is not None:
            p["mixer"] = L.init_mla(ks[0], cfg, dtype)
        else:
            p["mixer"] = L.init_attention(ks[0], cfg, dtype)
        if _layer_uses_moe(cfg, kind):
            p["ffn"] = L.init_moe(ks[1], cfg, dtype)
        elif cfg.family == "audio":
            p["ffn"] = L.init_gelu_mlp(ks[1], d, cfg.d_ff, dtype)
        else:
            p["ffn"] = L.init_swiglu(ks[1], d, cfg.d_ff, dtype)
        return p
    if kind == "rglru":
        return {
            "ln1": L.init_rmsnorm(d, dtype),
            "mixer": L.init_rglru_block(ks[0], cfg, dtype),
            "ln2": L.init_rmsnorm(d, dtype),
            "ffn": L.init_swiglu(ks[1], d, cfg.d_ff, dtype),
        }
    if kind == "rwkv":
        return {
            "ln1": L.init_layernorm(d, dtype),
            "mixer": L.init_rwkv6(ks[0], cfg, dtype),
            "ln2": L.init_layernorm(d, dtype),
            "ffn": L.init_rwkv6_channelmix(ks[1], cfg, dtype),
        }
    raise ValueError(f"unknown layer kind {kind}")


def apply_layer(p: PyTree, x: Array, cfg: ArchConfig, kind: str, *,
                pos: Array, cache: PyTree | None = None,
                shard=NO_SHARD) -> tuple[Array, PyTree | None]:
    if kind in ("attn", "attn_dense", "local_attn"):
        h = _norm(x, p["ln1"], cfg.norm_eps)
        window = cfg.local_window if kind == "local_attn" else None
        if cfg.mla is not None:
            a, new_cache = L.mla_fwd(p["mixer"], h, cfg, pos=pos,
                                     cache=cache, shard=shard)
        else:
            a, new_cache = L.attention_fwd(
                p["mixer"], h, cfg, pos=pos, cache=cache, causal=True,
                window=window, shard=shard)
        x = x + a
        h = _norm(x, p["ln2"], cfg.norm_eps)
        return x + _ffn_fwd(p["ffn"], h, cfg, shard), new_cache
    if kind == "rglru":
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        a, new_cache = L.rglru_block_fwd(p["mixer"], h, cfg, cache=cache,
                                         shard=shard)
        x = x + a
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        return x + L.swiglu_fwd(p["ffn"], h, shard=shard), new_cache
    if kind == "rwkv":
        h = L.layernorm(x, p["ln1"], cfg.norm_eps)
        a, c1 = L.rwkv6_timemix_fwd(p["mixer"], h, cfg, cache=(
            cache["tm"] if cache is not None else None), shard=shard)
        x = x + a
        h = L.layernorm(x, p["ln2"], cfg.norm_eps)
        f, c2 = L.rwkv6_channelmix_fwd(p["ffn"], h, cfg, cache=(
            cache["cm"] if cache is not None else None), shard=shard)
        new_cache = None if cache is None else {"tm": c1, "cm": c2}
        return x + f, new_cache
    raise ValueError(f"unknown layer kind {kind}")


# ---------------------------------------------------------------------------
# layer grouping (scan periods)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerGroups:
    prefix_kinds: tuple[str, ...]   # unrolled dense prefix (DeepSeek)
    period: tuple[str, ...]         # scanned pattern
    n_periods: int
    tail_kinds: tuple[str, ...]     # unrolled remainder


def layer_groups(cfg: ArchConfig) -> LayerGroups:
    kinds = list(cfg.layer_kinds)
    prefix = tuple(kinds[: cfg.dense_prefix])
    rest = kinds[cfg.dense_prefix:]
    period = tuple(cfg.layer_pattern)
    n_periods = len(rest) // len(period)
    tail = tuple(rest[n_periods * len(period):])
    return LayerGroups(prefix, period, n_periods, tail)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> PyTree:
    groups = layer_groups(cfg)
    keys = jax.random.split(key, 16)
    d = cfg.d_model
    p: dict[str, Any] = {
        "embed": L._dense_init(keys[0], (cfg.vocab, d), scale=0.02,
                               dtype=dtype),
        "final_norm": (L.init_layernorm(d, dtype) if cfg.family == "audio"
                       or cfg.layer_pattern == ("rwkv",)
                       else L.init_rmsnorm(d, dtype)),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L._dense_init(keys[1], (d, cfg.vocab), scale=0.02,
                                     dtype=dtype)

    if groups.prefix_kinds:
        p["prefix"] = [init_layer(k, cfg, kind, dtype) for k, kind in
                       zip(jax.random.split(keys[2], len(groups.prefix_kinds)),
                           groups.prefix_kinds)]
    if groups.n_periods:
        slot_params = []
        for si, kind in enumerate(groups.period):
            ks = jax.random.split(keys[3 + si % 8], groups.n_periods)
            stacked = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[init_layer(k, cfg, kind, dtype) for k in ks])
            slot_params.append(stacked)
        p["body"] = slot_params
    if groups.tail_kinds:
        p["tail"] = [init_layer(k, cfg, kind, dtype) for k, kind in
                     zip(jax.random.split(keys[11], len(groups.tail_kinds)),
                         groups.tail_kinds)]

    if cfg.enc_dec:
        p["enc"] = _init_encoder(keys[12], cfg, dtype)
        p["dec_pos"] = L._dense_init(keys[13], (cfg.max_target_len, d),
                                     scale=0.02, dtype=dtype)
        # decoder cross-attention per layer
        p["cross"] = [
            {"ln": L.init_rmsnorm(d, dtype),
             "attn": L.init_attention(keys[14], cfg, dtype)}
            for _ in range(cfg.n_layers)]
    if cfg.mtp:
        p["mtp"] = {
            "proj": L._dense_init(keys[15], (2 * d, d), dtype=dtype),
            "block": init_layer(keys[15], cfg, "attn_dense", dtype),
            "norm": L.init_rmsnorm(d, dtype),
        }
    return p


def _init_encoder(key, cfg: ArchConfig, dtype) -> PyTree:
    """Whisper encoder over precomputed (stub) frame embeddings."""
    d = cfg.d_model
    ks = jax.random.split(key, cfg.n_enc_layers + 1)
    enc_cfg = dataclasses.replace(cfg, mla=None, pos="none")
    return {
        "pos": L._dense_init(ks[0], (cfg.enc_context, d), scale=0.02,
                             dtype=dtype),
        "layers": [
            {"ln1": L.init_layernorm(d, dtype),
             "attn": L.init_attention(k, enc_cfg, dtype),
             "ln2": L.init_layernorm(d, dtype),
             "mlp": L.init_gelu_mlp(k, d, cfg.d_ff, dtype)}
            for k in ks[1:]],
        "ln_post": L.init_layernorm(d, dtype),
    }


# ---------------------------------------------------------------------------
# forward (training)
# ---------------------------------------------------------------------------

def _run_layers(params: PyTree, x: Array, cfg: ArchConfig, *, pos: Array,
                shard=NO_SHARD, remat: bool = True) -> Array:
    groups = layer_groups(cfg)
    for p_l, kind in zip(params.get("prefix", []), groups.prefix_kinds):
        x, _ = apply_layer(p_l, x, cfg, kind, pos=pos, shard=shard)

    if groups.n_periods:
        def body(carry, slot_params):
            h = carry
            for si, kind in enumerate(groups.period):
                h, _ = apply_layer(slot_params[si], h, cfg, kind,
                                   pos=pos, shard=shard)
            return h, None

        body_fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body_fn, x, params["body"])

    for p_l, kind in zip(params.get("tail", []), groups.tail_kinds):
        x, _ = apply_layer(p_l, x, cfg, kind, pos=pos, shard=shard)
    return x


def encoder_fwd(params: PyTree, enc_in: Array, cfg: ArchConfig,
                shard=NO_SHARD, remat: bool = False) -> Array:
    """Whisper encoder: precomputed conv-stub embeddings -> memory."""
    e = params["enc"]
    x = enc_in + e["pos"][None, : enc_in.shape[1]]

    def one(x, lp):
        h = L.layernorm(x, lp["ln1"], cfg.norm_eps)
        a, _ = L.attention_fwd(lp["attn"], h, cfg, pos=jnp.zeros(
            x.shape[:2], jnp.int32), causal=False, shard=shard)
        x = x + a
        h = L.layernorm(x, lp["ln2"], cfg.norm_eps)
        return x + L.gelu_mlp_fwd(lp["mlp"], h, shard=shard)

    one_fn = jax.checkpoint(one) if remat else one
    for lp in e["layers"]:
        x = one_fn(x, lp)
    return L.layernorm(x, e["ln_post"], cfg.norm_eps)


def _dec_layers_with_cross(params: PyTree, x: Array, memory: Array,
                           cfg: ArchConfig, *, pos: Array,
                           self_caches=None, cross_kv=None,
                           shard=NO_SHARD, remat: bool = False):
    """Whisper decoder: per layer self-attn -> cross-attn -> mlp.

    Layers are unrolled (whisper-tiny: 4) with optional per-layer remat.
    ``cross_kv`` precomputed (k, v) per layer for decode.
    """
    groups = layer_groups(cfg)
    kinds = list(groups.prefix_kinds) + list(groups.period) * \
        groups.n_periods + list(groups.tail_kinds)
    layer_list = _unstack_layers(params, groups)
    new_self = []

    def one(x, p_l, cp, cache_i, ckv):
        h = _norm(x, p_l["ln1"], cfg.norm_eps)
        a, nc = L.attention_fwd(p_l["mixer"], h, cfg, pos=pos,
                                cache=cache_i, causal=True, shard=shard)
        x = x + a
        h = L.rmsnorm(x, cp["ln"], cfg.norm_eps)
        # cross attention: keys/values from encoder memory
        q = jnp.einsum("bsd,dhk->bshk", h, cp["attn"]["wq"])
        if ckv is not None:
            ck, cv = ckv
        else:
            ck = jnp.einsum("bsd,dhk->bshk", memory, cp["attn"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", memory, cp["attn"]["wv"])
        o = L._sdpa(q, ck, cv, causal=False, window=None, shard=shard)
        x = x + jnp.einsum("bshk,hkd->bsd", o, cp["attn"]["wo"])
        h = _norm(x, p_l["ln2"], cfg.norm_eps)
        return x + _ffn_fwd(p_l["ffn"], h, cfg, shard), nc

    one_fn = jax.checkpoint(one, static_argnums=()) if remat else one
    for li, (p_l, kind) in enumerate(zip(layer_list, kinds)):
        cache_i = None if self_caches is None else self_caches[li]
        ckv = None if cross_kv is None else cross_kv[li]
        x, nc = one_fn(x, p_l, params["cross"][li], cache_i, ckv)
        new_self.append(nc)
    return x, new_self


def _unstack_layers(params: PyTree, groups: LayerGroups) -> list[PyTree]:
    out = list(params.get("prefix", []))
    if groups.n_periods:
        for pi in range(groups.n_periods):
            for si in range(len(groups.period)):
                out.append(jax.tree.map(lambda a: a[pi],
                                        params["body"][si]))
    out += list(params.get("tail", []))
    return out


def chunked_ce_loss(x: Array, unembed: Array, labels: Array, mask: Array,
                    *, chunk: int = 512, shard=NO_SHARD) -> Array:
    """Mean next-token CE without materializing [B,S,V] logits: sequence is
    processed in chunks; the vocab axis inherits the unembed sharding so
    each chunk's logits live sharded on "model"."""
    b, s, d = x.shape
    n_chunk = max(1, s // chunk)
    xc = x.reshape(b, n_chunk, s // n_chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, n_chunk, s // n_chunk).swapaxes(0, 1)
    mc = mask.reshape(b, n_chunk, s // n_chunk).swapaxes(0, 1)

    def one(args):
        xx, ll, mm = args
        logits = jnp.einsum("bsd,dv->bsv", xx, unembed,
                            preferred_element_type=jnp.float32)
        logits = shard(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe_ll = jnp.clip(ll, 0)       # masked labels may be sentinels
        gold = jnp.take_along_axis(
            logits, safe_ll[..., None].astype(jnp.int32), axis=-1)[..., 0]
        nll = (lse - gold) * mm
        return jnp.sum(nll), jnp.sum(mm)

    nlls, cnts = jax.lax.map(one, (xc, lc, mc))
    return jnp.sum(nlls) / jnp.maximum(jnp.sum(cnts), 1.0)


def train_forward(params: PyTree, batch: dict[str, Array], cfg: ArchConfig,
                  *, shard=NO_SHARD, remat: bool = True) -> Array:
    """Full training loss for one (micro)batch. ``batch`` keys per family:
    tokens/labels/mask (+pos3 for vlm, +vision_embeds; +enc_input for
    audio)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    x = shard(x, "act_resid")

    if cfg.frontend == "vision_stub":
        nv = cfg.n_vision_tokens
        x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype),
                             x[:, nv:]], axis=1) if nv else x
        pos = batch["pos3"]
    elif cfg.pos == "mrope":
        pos = batch["pos3"]
    else:
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])

    if cfg.enc_dec:
        memory = encoder_fwd(params, batch["enc_input"], cfg, shard,
                             remat=remat)
        x = x + params["dec_pos"][None, :s]
        x, _ = _dec_layers_with_cross(params, x, memory, cfg, pos=pos,
                                      shard=shard, remat=remat)
        x = L.layernorm(x, params["final_norm"], cfg.norm_eps)
        return chunked_ce_loss(x, unembed, batch["labels"], batch["mask"],
                               shard=shard)

    x = _run_layers(params, x, cfg, pos=pos, shard=shard, remat=remat)
    x = (L.layernorm(x, params["final_norm"], cfg.norm_eps)
         if "bias" in params["final_norm"]
         else L.rmsnorm(x, params["final_norm"], cfg.norm_eps))
    loss = chunked_ce_loss(x, unembed, batch["labels"], batch["mask"],
                           shard=shard)

    if cfg.mtp:
        # multi-token prediction (DeepSeek-V3): one extra block predicts
        # t+2 from [h_t ; emb(t+1)]
        emb_next = jnp.concatenate(
            [params["embed"][tokens[:, 1:]],
             jnp.zeros_like(x[:, :1])], axis=1)
        h = jnp.concatenate([x, emb_next.astype(x.dtype)], axis=-1)
        h = jnp.einsum("bsd,dk->bsk", h, params["mtp"]["proj"])
        h, _ = apply_layer(params["mtp"]["block"], h, cfg, "attn_dense",
                           pos=pos, shard=shard)
        h = L.rmsnorm(h, params["mtp"]["norm"], cfg.norm_eps)
        labels2 = jnp.concatenate(
            [batch["labels"][:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        mask2 = jnp.concatenate(
            [batch["mask"][:, 1:], jnp.zeros_like(batch["mask"][:, :1])],
            axis=1)
        loss = loss + 0.1 * chunked_ce_loss(h, unembed, labels2, mask2,
                                            shard=shard)

    if cfg.moe is not None:
        # one representative aux-loss evaluation on the embedding output
        # (cheap proxy; per-layer aux summing is a config option)
        pass
    return loss


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> PyTree:
    """Per-layer cache stacked per scan slot (mirrors param layout)."""
    groups = layer_groups(cfg)

    def one(kind, lead):
        if kind in ("attn", "attn_dense", "local_attn"):
            s_max = (min(max_len, cfg.local_window)
                     if kind == "local_attn" else max_len)
            if kind == "local_attn":
                # ring buffer (O(window) memory) with absolute positions
                hk, hd = cfg.n_kv_heads, cfg.head_dim
                return {"k": jnp.zeros((*lead, batch, s_max, hk, hd),
                                       dtype),
                        "v": jnp.zeros((*lead, batch, s_max, hk, hd),
                                       dtype),
                        "pos": jnp.full((*lead, batch, s_max), -1,
                                        jnp.int32),
                        "length": jnp.zeros(lead, jnp.int32) if lead else
                        jnp.int32(0)}
            if cfg.mla is not None:
                m = cfg.mla
                c = {"latent": jnp.zeros((*lead, batch, s_max, m.kv_rank),
                                         dtype),
                     "k_rope": jnp.zeros((*lead, batch, s_max, 1, m.d_rope),
                                         dtype),
                     "length": jnp.zeros(lead, jnp.int32) if lead else
                     jnp.int32(0)}
            else:
                hk, hd = cfg.n_kv_heads, cfg.head_dim
                c = {"k": jnp.zeros((*lead, batch, s_max, hk, hd), dtype),
                     "v": jnp.zeros((*lead, batch, s_max, hk, hd), dtype),
                     "length": jnp.zeros(lead, jnp.int32) if lead else
                     jnp.int32(0)}
            return c
        if kind == "rglru":
            d = cfg.d_model
            return {"h": jnp.zeros((*lead, batch, d), jnp.float32),
                    "conv": jnp.zeros((*lead, batch, 3, d), dtype)}
        if kind == "rwkv":
            d = cfg.d_model
            hd = cfg.rwkv_head_dim
            return {"tm": {"x_prev": jnp.zeros((*lead, batch, d), dtype),
                           "state": jnp.zeros((*lead, batch, d // hd, hd,
                                               hd), jnp.float32)},
                    "cm": {"x_prev": jnp.zeros((*lead, batch, d), dtype)}}
        raise ValueError(kind)

    cache: dict[str, Any] = {}
    if groups.prefix_kinds:
        cache["prefix"] = [one(k, ()) for k in groups.prefix_kinds]
    if groups.n_periods:
        cache["body"] = [one(k, (groups.n_periods,)) for k in groups.period]
    if groups.tail_kinds:
        cache["tail"] = [one(k, ()) for k in groups.tail_kinds]
    return cache


def decode_step(params: PyTree, cache: PyTree, tokens: Array,
                cfg: ArchConfig, *, pos: Array | None = None,
                shard=NO_SHARD) -> tuple[Array, PyTree]:
    """One decode step: tokens [B, 1] -> (logits [B, 1, V], new cache)."""
    groups = layer_groups(cfg)
    b = tokens.shape[0]
    x = params["embed"][tokens]
    x = shard(x, "act_resid")
    if pos is None:
        length = 0
        if groups.prefix_kinds and "length" in cache["prefix"][0]:
            length = cache["prefix"][0]["length"]
        elif groups.n_periods:
            for si, kind in enumerate(groups.period):
                if kind in ("attn", "attn_dense", "local_attn"):
                    length = cache["body"][si]["length"][0]
                    break
        pos = jnp.broadcast_to(jnp.asarray(length)[None, None], (b, 1))

    new_cache: dict[str, Any] = {}
    if groups.prefix_kinds:
        ncs = []
        for p_l, kind, c in zip(params["prefix"], groups.prefix_kinds,
                                cache["prefix"]):
            x, nc = apply_layer(p_l, x, cfg, kind, pos=pos, cache=c,
                                shard=shard)
            ncs.append(nc)
        new_cache["prefix"] = ncs

    if groups.n_periods:
        def body(carry, xs):
            h = carry
            slot_params, slot_caches = xs
            ncs = []
            for si, kind in enumerate(groups.period):
                h, nc = apply_layer(slot_params[si], h, cfg, kind, pos=pos,
                                    cache=slot_caches[si], shard=shard)
                ncs.append(nc)
            return h, ncs

        x, body_caches = jax.lax.scan(body, x,
                                      (params["body"], cache["body"]))
        new_cache["body"] = body_caches

    if groups.tail_kinds:
        ncs = []
        for p_l, kind, c in zip(params["tail"], groups.tail_kinds,
                                cache["tail"]):
            x, nc = apply_layer(p_l, x, cfg, kind, pos=pos, cache=c,
                                shard=shard)
            ncs.append(nc)
        new_cache["tail"] = ncs

    x = (L.layernorm(x, params["final_norm"], cfg.norm_eps)
         if "bias" in params["final_norm"]
         else L.rmsnorm(x, params["final_norm"], cfg.norm_eps))
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", x, unembed,
                        preferred_element_type=jnp.float32)
    return shard(logits, "logits"), new_cache


def forward_logits(params: PyTree, tokens: Array, cfg: ArchConfig, *,
                   shard=NO_SHARD) -> Array:
    """Full-sequence logits [B,S,V] (tests + examples; training uses the
    chunked loss instead to avoid materializing this)."""
    b, s = tokens.shape
    x = params["embed"][tokens]
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = _run_layers(params, x, cfg, pos=pos, shard=shard, remat=False)
    x = _norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("bsd,dv->bsv", x, unembed,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# parameter counting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k, jnp.float32),
        jax.random.PRNGKey(0))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    if not active_only or cfg.moe is None:
        return total
    mo = cfg.moe
    ff = mo.d_expert or cfg.d_ff
    per_expert = 3 * cfg.d_model * ff
    n_moe_layers = sum(1 for k in cfg.layer_kinds
                       if _layer_uses_moe(cfg, k))
    dead = n_moe_layers * per_expert * (mo.n_experts - mo.top_k)
    return total - dead
