"""Architecture configuration + registry.

One ``ArchConfig`` instance per assigned architecture lives in
``repro/configs/<id>.py`` with the exact published numbers; the same
dataclass drives reduced smoke configs and the dry-run input specs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

_REGISTRY: dict[str, "ArchConfig"] = {}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0            # shared (always-on) experts, DeepSeekMoE
    d_expert: int | None = None  # per-expert ffn width (None -> d_ff)
    capacity_factor: float = 1.25
    router_aux_free: bool = False  # DeepSeek-V3 aux-loss-free bias update


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3, MiniCPM3)."""

    q_rank: int                  # query low-rank compression dim
    kv_rank: int                 # KV latent dim (this is what decode caches)
    d_nope: int                  # per-head non-rotary dim
    d_rope: int                  # per-head rotary dim (shared key rope)
    d_v: int                     # per-head value dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None    # None -> d_model // n_heads
    attn_bias: bool = False      # QKV bias (Qwen1.5)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    pos: str = "rope"            # rope | mrope | none | learned
    layer_pattern: tuple[str, ...] = ("attn",)   # period of layer kinds
    dense_prefix: int = 0        # leading dense layers before MoE (DeepSeek)
    local_window: int = 2048     # window for "local_attn" layers
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mtp: bool = False            # multi-token-prediction head (DeepSeek-V3)
    enc_dec: bool = False        # Whisper
    n_enc_layers: int = 0
    enc_context: int = 1500      # encoder frames (Whisper audio stub)
    max_target_len: int = 448    # decoder position cap (Whisper)
    frontend: str = "none"       # none | audio_stub | vision_stub
    n_vision_tokens: int = 0     # stub patch-embedding tokens (Qwen2-VL)
    # rwkv6
    rwkv_head_dim: int = 64
    # notes for DESIGN/EXPERIMENTS (sub-quadratic support etc.)
    subquadratic: bool = False   # True -> long_500k decode supported
    note: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else (
            self.d_model // self.n_heads)

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Expanded per-layer kind list of length n_layers."""
        kinds = []
        if self.dense_prefix:
            kinds += ["attn_dense"] * self.dense_prefix
        i = 0
        while len(kinds) < self.n_layers:
            kinds.append(self.layer_pattern[i % len(self.layer_pattern)])
            i += 1
        return tuple(kinds[: self.n_layers])

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for roofline
        MODEL_FLOPS = 6*N*D."""
        from . import model  # lazy; model computes exact shapes
        return model.count_params(self)

    def active_param_count(self) -> int:
        from . import model
        return model.count_params(self, active_only=True)


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # configs register on import
        import importlib
        importlib.import_module("repro.configs")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import importlib
    importlib.import_module("repro.configs")
    return sorted(_REGISTRY)
