"""Model zoo for the assigned architectures (LM-family transformers).

The RTNN technique itself is 3-D spatial search and does not apply inside
these forward passes (DESIGN.md section 4 Arch-applicability); the zoo is a
first-class feature of the same runtime: same mesh, launcher, checkpointing
and dry-run machinery as the neighbor-search core.
"""
from .config import ArchConfig, MLAConfig, MoEConfig, register, get_config, list_configs
from .model import init_params, train_forward, decode_step, init_decode_cache
