"""Pallas TPU kernel: tiled pairwise squared distances.

The paper's Step 2 (IS-shader sphere test) re-expressed for the MXU
(DESIGN.md section 2): ||q - p||^2 = ||q||^2 + ||p||^2 - 2 q.p^T, where the
cross term is a (TQ x D) @ (D x TP) matmul on the systolic array. The
coordinate dimension D is padded to 8 sublanes in the wrapper (zeros do not
change distances) so the MXU operand is hardware-aligned.

Grid: (Nq / TQ, Np / TP); each step computes one [TQ, TP] distance tile
entirely in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TQ = 256
DEFAULT_TP = 512
COORD_PAD = 8  # sublane-aligned coordinate dim (3 -> 8)


def _distance_kernel(q_ref, pt_ref, out_ref):
    """q_ref [TQ, 8] f32; pt_ref [8, TP] f32 (pre-transposed); out [TQ, TP]."""
    q = q_ref[...]
    p = pt_ref[...]
    qn = jnp.sum(q * q, axis=1, keepdims=True)                 # [TQ, 1]
    pn = jnp.sum(p * p, axis=0, keepdims=True)                 # [1, TP]
    cross = jnp.dot(q, p, preferred_element_type=jnp.float32)  # MXU
    out_ref[...] = jnp.maximum(qn + pn - 2.0 * cross, 0.0)


@functools.partial(jax.jit, static_argnames=("tq", "tp", "interpret"))
def distance_tile(
    q: jax.Array,
    p: jax.Array,
    *,
    tq: int = DEFAULT_TQ,
    tp: int = DEFAULT_TP,
    interpret: bool = True,
) -> jax.Array:
    """Pairwise squared distances [Nq, Np] of q [Nq, 3] and p [Np, 3].

    Shapes are padded to tile multiples; padding rows produce garbage
    distances that the caller slices away.
    """
    nq, _ = q.shape
    npts, _ = p.shape
    nq_pad = (-nq) % tq
    np_pad = (-npts) % tp
    qp = jnp.pad(q.astype(jnp.float32), ((0, nq_pad), (0, COORD_PAD - 3)))
    pp = jnp.pad(p.astype(jnp.float32), ((0, np_pad), (0, COORD_PAD - 3)))
    pt = pp.T  # [8, Np_pad]

    grid = (qp.shape[0] // tq, pt.shape[1] // tp)
    out = pl.pallas_call(
        _distance_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, COORD_PAD), lambda i, j: (i, 0)),
            pl.BlockSpec((COORD_PAD, tp), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tq, tp), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], pt.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(qp, pt)
    return out[:nq, :npts]
