"""Pure-jnp oracles for every kernel in this package.

These are the ground truth the Pallas kernels are validated against
(tests/test_kernels_*.py sweep shapes/dtypes and assert_allclose), and the
brute-force neighbor-search oracle the whole library is validated against.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


def pairwise_d2(q: Array, p: Array) -> Array:
    """Squared Euclidean distances [Nq, Np] between q [Nq, 3] and p [Np, 3].

    Expanded form |q|^2 + |p|^2 - 2 q.p^T: the -2 q.p^T term is a matmul —
    on TPU this is the MXU formulation the distance kernel uses (DESIGN.md
    section 2, Step 2); the oracle uses the same math so tolerance behaviour
    matches.
    """
    qn = jnp.sum(q * q, axis=-1, keepdims=True)            # [Nq, 1]
    pn = jnp.sum(p * p, axis=-1, keepdims=True).T          # [1, Np]
    cross = q @ p.T                                         # [Nq, Np] (MXU)
    return jnp.maximum(qn + pn - 2.0 * cross, 0.0)


def topk_select(d2: Array, idx: Array, k: int) -> tuple[Array, Array]:
    """Smallest-k selection along the last axis.

    ``d2`` [..., M] distances (inf = invalid), ``idx`` [..., M] candidate ids
    (-1 = invalid). Returns ([..., k] d2, [..., k] idx), ascending, padded
    with (inf, -1). Ties broken by candidate id for determinism.
    """
    m = d2.shape[-1]
    if m < k:
        pad = [(0, 0)] * (d2.ndim - 1) + [(0, k - m)]
        d2 = jnp.pad(d2, pad, constant_values=jnp.inf)
        idx = jnp.pad(idx, pad, constant_values=-1)
        m = k
    # tie-break on id: compose a sortable key
    order = jnp.argsort(d2, axis=-1, stable=True)
    d2s = jnp.take_along_axis(d2, order, axis=-1)[..., :k]
    idxs = jnp.take_along_axis(idx, order, axis=-1)[..., :k]
    idxs = jnp.where(jnp.isinf(d2s), -1, idxs)
    return d2s, idxs


@partial(jax.jit, static_argnames=("k", "mode", "chunk"))
def brute_force_search(
    points: Array,
    queries: Array,
    radius: float,
    k: int,
    mode: str = "knn",
    chunk: int = 512,
) -> tuple[Array, Array, Array]:
    """Exhaustive oracle. Returns (idx [Nq,k], d2 [Nq,k], counts [Nq]).

    Both modes return the *nearest* k within ``radius`` (for range search
    any k inside r is acceptable per the paper's bounded interface; nearest-k
    is a deterministic valid choice, which makes oracle comparison exact).
    """
    nq = queries.shape[0]
    npad = (-nq) % chunk
    qp = jnp.pad(queries, ((0, npad), (0, 0)))
    r2 = jnp.float32(radius) ** 2
    cand_idx = jnp.arange(points.shape[0], dtype=jnp.int32)

    def one_chunk(qc):
        d2 = pairwise_d2(qc, points)
        d2 = jnp.where(d2 <= r2, d2, jnp.inf)
        idx = jnp.broadcast_to(cand_idx, d2.shape)
        idx = jnp.where(jnp.isinf(d2), -1, idx)
        d2k, idxk = topk_select(d2, idx, k)
        cnt = jnp.sum((~jnp.isinf(d2k)).astype(jnp.int32), axis=-1)
        return d2k, idxk, cnt

    d2c, idxc, cntc = jax.lax.map(
        one_chunk, qp.reshape(-1, chunk, 3))
    return (
        idxc.reshape(-1, k)[:nq],
        d2c.reshape(-1, k)[:nq],
        cntc.reshape(-1)[:nq],
    )


def streaming_topk_ref(d2_tiles: Array, idx_tiles: Array, k: int
                       ) -> tuple[Array, Array]:
    """Oracle for the kernel's streaming top-k merge: given candidate tiles
    [T, n_tiles, tile_m] it must equal top-k over the flattened last axes."""
    t = d2_tiles.shape[0]
    d2 = d2_tiles.reshape(t, -1)
    idx = idx_tiles.reshape(t, -1)
    return topk_select(d2, idx, k)


def range_count_ref(q: Array, p: Array, radius: float) -> Array:
    """Number of points within ``radius`` per query (Step-2 call counter for
    the fig08 benchmark)."""
    d2 = pairwise_d2(q, p)
    return jnp.sum(d2 <= radius**2, axis=-1)
