"""Pallas TPU kernel: range-search candidate counting.

Counts, per query, the candidates within r (the paper's IS-call / Step-2
counter — fig08 benchmark — and the counting half of bounded range search).
Lane-partial sums are accumulated in a [TQ, 128] block across candidate
tiles and reduced in the wrapper, keeping every store lane-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TQ = 256
DEFAULT_TM = 512
COORD_PAD = 8
LANES = 128


def _range_count_kernel(q_ref, pt_ref, idx_ref, out_ref, *, r2: float,
                        tm: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[...]
    p = pt_ref[0]
    idx = idx_ref[0][None, :]
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    pn = jnp.sum(p * p, axis=0, keepdims=True)
    cross = jnp.dot(q, p, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(qn + pn - 2.0 * cross, 0.0)
    hit = (d2 <= r2) & jnp.broadcast_to(idx >= 0, d2.shape)
    tq = q.shape[0]
    partial = jnp.sum(
        hit.astype(jnp.int32).reshape(tq, tm // LANES, LANES), axis=1)
    out_ref[...] = out_ref[...] + partial


@functools.partial(
    jax.jit, static_argnames=("r2", "tq", "tm", "interpret"))
def range_count(
    q: jax.Array,          # [Nq, 3], Nq % tq == 0
    wnd_pos: jax.Array,    # [n_tiles, M, 3]
    wnd_idx: jax.Array,    # [n_tiles, M]
    *,
    r2: float,
    tq: int = DEFAULT_TQ,
    tm: int = DEFAULT_TM,
    interpret: bool = True,
) -> jax.Array:
    """Per-query count of window candidates within sqrt(r2). Returns [Nq]."""
    assert tm % LANES == 0
    n_tiles, m, _ = wnd_pos.shape
    m_pad = (-m) % tm
    wnd_pos = jnp.pad(wnd_pos.astype(jnp.float32),
                      ((0, 0), (0, m_pad), (0, COORD_PAD - 3)))
    wnd_idx = jnp.pad(wnd_idx, ((0, 0), (0, m_pad)), constant_values=-1)
    wnd_pos_t = jnp.swapaxes(wnd_pos, 1, 2)
    qp = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, COORD_PAD - 3)))
    n_m = wnd_pos_t.shape[2] // tm

    kernel = functools.partial(_range_count_kernel, r2=float(r2), tm=tm)
    out = pl.pallas_call(
        kernel,
        grid=(n_tiles, n_m),
        in_specs=[
            pl.BlockSpec((tq, COORD_PAD), lambda i, j: (i, 0)),
            pl.BlockSpec((1, COORD_PAD, tm), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, tm), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tq, LANES), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * tq, LANES), jnp.int32),
        interpret=interpret,
    )(qp, wnd_pos_t, wnd_idx)
    return jnp.sum(out, axis=1)
