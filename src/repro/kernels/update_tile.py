"""Pallas TPU kernel: fused re-binning + motion statistics for dynamic
scenes (DESIGN.md section 7).

One pass over the moved points produces everything the incremental grid
update needs: the clipped integer cell assignment (consumed by the dense
scatter in ``core/grid.py`` AND by query scheduling on the self-query fast
path), the out-of-bounds count (points whose true cell left the frozen
grid), and the max squared displacement vs the plan-anchor positions (the
temporal-coherence staleness statistic). The jnp path materializes three
separate intermediates for these; here each [TN, 8] position tile is read
from VMEM once and reduced in-register.

Grid: (N / TN,). Coordinates are padded 3 -> 8 sublanes like the other
kernels in this package (zero columns change no statistic: they are masked
out of the bounds test and contribute 0 to displacement).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TN = 256
COORD_PAD = 8


@functools.partial(jax.jit,
                   static_argnames=("spec", "mask_parked", "tn",
                                    "interpret"))
def bin_disp_tile(
    points: jax.Array,
    anchor_points: jax.Array,
    spec,                     # core.types.GridSpec (hashable/static)
    *,
    origin: jax.Array | None = None,
    mask_parked: bool = False,
    tn: int = DEFAULT_TN,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused binning + stats of ``points`` [N, 3] against ``anchor_points``.

    Returns ``(ccoord [N, 3] int32 clipped, oob int32, max_disp2 f32)`` —
    bit-identical to the jnp path in ``core.grid._bin_and_stats``.
    ``origin`` overrides the static spec origin (sharded slabs);
    ``mask_parked`` excludes rows parked at the slab-padding sentinel from
    both statistics (tested in-register against ``types.PARK_THRESHOLD``,
    so the mask costs no extra pass).
    """
    from ..core.types import PARK_THRESHOLD
    n = points.shape[0]
    npad = (-n) % tn
    # rows: edge-replicate (real coordinates, masked out of the reductions
    # by row index); columns: zero-pad 3 -> 8 sublanes
    pp = jnp.pad(points.astype(jnp.float32), ((0, npad), (0, 0)),
                 mode="edge")
    ap = jnp.pad(anchor_points.astype(jnp.float32), ((0, npad), (0, 0)),
                 mode="edge")
    pp = jnp.pad(pp, ((0, 0), (0, COORD_PAD - 3)))
    ap = jnp.pad(ap, ((0, 0), (0, COORD_PAD - 3)))
    n_tiles = pp.shape[0] // tn

    if origin is None:
        origin = jnp.asarray(tuple(spec.origin) + (0.0,) * (COORD_PAD - 3),
                             jnp.float32)[None, :]
    else:
        origin = jnp.concatenate(
            [origin.astype(jnp.float32).reshape(3),
             jnp.zeros((COORD_PAD - 3,), jnp.float32)])[None, :]
    hi = jnp.asarray(tuple(d - 1 for d in spec.dims)
                     + (0,) * (COORD_PAD - 3), jnp.int32)[None, :]
    inv_cell = 1.0 / spec.cell_size

    def kernel(p_ref, a_ref, o_ref, h_ref, cc_ref, oob_ref, d2_ref):
        i = pl.program_id(0)
        p = p_ref[...]                                      # [TN, 8]
        a = a_ref[...]
        o = o_ref[...]                                      # [1, 8]
        h = h_ref[...]                                      # [1, 8]
        axis = jax.lax.broadcasted_iota(jnp.int32, (tn, COORD_PAD), 1)
        real_col = axis < 3
        row = i * tn + jax.lax.broadcasted_iota(jnp.int32, (tn, 1), 0)
        real_row = row < n                                  # [TN, 1]
        if mask_parked:
            parked = jnp.any(
                (jnp.abs(p) >= jnp.float32(PARK_THRESHOLD)) & real_col,
                axis=1, keepdims=True)                      # [TN, 1]
            real_row = real_row & jnp.logical_not(parked)

        c = jnp.floor((p - o) * inv_cell).astype(jnp.int32)
        escaped = ((c < 0) | (c > h)) & real_col
        oob_row = jnp.any(escaped, axis=1, keepdims=True)   # [TN, 1]
        oob_ref[0, 0] = jnp.sum(
            (oob_row & real_row).astype(jnp.int32))

        d = p - a                                           # pad cols: 0
        d2 = jnp.sum(d * d, axis=1, keepdims=True)          # [TN, 1]
        d2_ref[0, 0] = jnp.max(jnp.where(real_row, d2, 0.0))

        cc_ref[...] = jnp.clip(c, 0, h)

    cc, oob_part, d2_part = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tn, COORD_PAD), lambda i: (i, 0)),
            pl.BlockSpec((tn, COORD_PAD), lambda i: (i, 0)),
            pl.BlockSpec((1, COORD_PAD), lambda i: (0, 0)),
            pl.BlockSpec((1, COORD_PAD), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tn, COORD_PAD), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pp.shape[0], COORD_PAD), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pp, ap, origin, hi)
    return cc[:n, :3], jnp.sum(oob_part), jnp.max(d2_part)
