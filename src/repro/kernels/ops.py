"""jit'd wrappers around the Pallas kernels + the drop-in search path.

``window_search_pallas`` matches ``core.search.window_search``'s signature
so `SearchOpts(use_pallas=True)` swaps the jnp tile path for the fused
kernel path. On this CPU container the kernels run in interpret mode
(correctness); on TPU set ``interpret=False`` via `PALLAS_INTERPRET=0`
(knob reference: DESIGN.md section 4).

Tile-window semantics: each Morton-contiguous query tile gathers ONE shared
cell window (the union of its members' windows) — that is the coherence
payoff of the paper's section-4 scheduling: neighbors of adjacent queries
come from the same VMEM-resident candidate tile. Only the candidate *ids*
are staged ([n_tiles, M] int32); the fused kernel gathers positions from
the coordinate table inside VMEM (see knn_tile.py), so the old
[n_tiles, M, 3] window-position array never exists in HBM. The sphere-test
skip deviation of this path is documented in DESIGN.md section 2.

``qcells`` lets the caller (the QueryExecutor) pass host-resident query
cell coordinates so the tile-window shape — a host-static quantity — is
derived without a mid-dispatch device sync; standalone callers omit it and
pay one small transfer here instead.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .distance_tile import distance_tile
from .knn_tile import knn_tile
from .range_tile import range_count
from .update_tile import bin_disp_tile

INTERPRET = os.environ.get("PALLAS_INTERPRET", "1") != "0"


def window_search_pallas(
    grid,                 # core.types.CellGrid
    points: jax.Array,
    queries: jax.Array,   # [Nq, 3]
    spec,                 # core.types.GridSpec
    w: int,
    radius: float,
    k: int,
    skip_test: bool,      # accepted for signature parity; see module note
    tile: int = 256,
    qcells: np.ndarray | None = None,   # [Nq, 3] host cell coords (optional)
) -> tuple[jax.Array, jax.Array, jax.Array]:
    nq = queries.shape[0]
    npad = (-nq) % tile
    if npad:
        # edge-replicate to the tile multiple (same padding discipline as
        # window_search and the executor's selections): padded rows repeat
        # the last real query, so they cannot distort the shared tile-window
        # anchors below the way zero rows (origin cell) would
        queries = jnp.pad(queries, ((0, npad), (0, 0)), mode="edge")
        if qcells is not None:
            qcells = np.pad(np.asarray(qcells), ((0, npad), (0, 0)),
                            mode="edge")
    n_tiles = (nq + npad) // tile
    dims = np.asarray(spec.dims)
    cap = spec.capacity

    if qcells is None:
        # standalone use: one small host transfer to size the tile windows
        qcells = np.asarray(jax.device_get(spec.cell_of(queries)))
    qc_t = np.asarray(qcells, np.int64).reshape(n_tiles, tile, 3)
    lo = qc_t.min(axis=1) - w
    hi = qc_t.max(axis=1) + w
    spread = (hi - lo + 1).max(axis=0)                    # [3] host-static
    ws = tuple(int(min(s, d)) for s, d in zip(spread, dims))
    anchors = jnp.asarray(np.clip(lo, 0, dims - np.asarray(ws)), jnp.int32)

    def gather_one(a):
        blk = jax.lax.dynamic_slice(
            grid.dense, (a[0], a[1], a[2], 0), (*ws, cap))
        return blk.reshape(-1)

    wnd_idx = jax.vmap(gather_one)(anchors)               # [n_tiles, M] i32
    d2, idx = knn_tile(
        queries, points, wnd_idx, k=k, r2=float(radius) ** 2,
        skip_test=False, tq=tile, interpret=INTERPRET)
    counts = jnp.sum((idx >= 0).astype(jnp.int32), axis=1)
    return idx[:nq], d2[:nq], counts[:nq]


__all__ = ["bin_disp_tile", "distance_tile", "knn_tile", "range_count",
           "window_search_pallas", "INTERPRET"]
