"""jit'd wrappers around the Pallas kernels + the drop-in search path.

``window_search_pallas`` matches ``core.search.window_search``'s signature
so `SearchOpts(use_pallas=True)` swaps the jnp tile path for the fused
kernel path. On this CPU container the kernels run in interpret mode
(correctness); on TPU set ``interpret=False`` via `PALLAS_INTERPRET=0`.

Tile-window semantics: each Morton-contiguous query tile gathers ONE shared
cell window (the union of its members' windows) — that is the coherence
payoff of the paper's section-4 scheduling: neighbors of adjacent queries
come from the same VMEM-resident candidate tile. Because the shared window
is a superset of any member's own window, the r^2 filter is always applied
here (the jnp per-query path implements the paper's skip-sphere-test
variant; in this fused kernel the distance is a byproduct of selection, so
the skip saves nothing — documented deviation).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .distance_tile import distance_tile
from .knn_tile import knn_tile
from .range_tile import range_count

INTERPRET = os.environ.get("PALLAS_INTERPRET", "1") != "0"


def window_search_pallas(
    grid,                 # core.types.CellGrid
    points: jax.Array,
    queries: jax.Array,   # [Nq, 3], Nq % tile == 0 (caller pads)
    spec,                 # core.types.GridSpec
    w: int,
    radius: float,
    k: int,
    skip_test: bool,      # accepted for signature parity; see module note
    tile: int = 256,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    nq = queries.shape[0]
    assert nq % tile == 0
    n_tiles = nq // tile
    dims = np.asarray(spec.dims)
    cap = spec.capacity

    qcells = spec.cell_of(queries)                        # [Nq, 3]
    qc_t = qcells.reshape(n_tiles, tile, 3)
    lo = jnp.min(qc_t, axis=1) - w
    hi = jnp.max(qc_t, axis=1) + w
    spread = jax.device_get(jnp.max(hi - lo + 1, axis=0)) # [3] host-static
    ws = tuple(int(min(s, d)) for s, d in zip(spread, dims))
    anchors = jnp.clip(lo, 0, jnp.asarray(dims - np.asarray(ws), jnp.int32))

    def gather_one(a):
        blk = jax.lax.dynamic_slice(
            grid.dense, (a[0], a[1], a[2], 0), (*ws, cap))
        return blk.reshape(-1)

    wnd_idx = jax.vmap(gather_one)(anchors)               # [n_tiles, M]
    wnd_pos = points[jnp.clip(wnd_idx, 0, points.shape[0] - 1)]
    # park invalid slots far away so they never enter the top-K even before
    # the idx mask (belt and braces for fp edge cases)
    wnd_pos = jnp.where((wnd_idx < 0)[..., None], jnp.float32(1e30), wnd_pos)

    d2, idx = knn_tile(
        queries, wnd_pos, wnd_idx, k=k, r2=float(radius) ** 2,
        skip_test=False, tq=tile, interpret=INTERPRET)
    counts = jnp.sum((idx >= 0).astype(jnp.int32), axis=1)
    return idx, d2, counts


__all__ = ["distance_tile", "knn_tile", "range_count",
           "window_search_pallas", "INTERPRET"]
