"""jit'd wrappers around the Pallas kernels + the drop-in search path.

``window_search_pallas`` matches ``core.search.window_search``'s signature
so `SearchOpts(use_pallas=True)` swaps the jnp tile path for the fused
kernel path. On this CPU container the kernels run in interpret mode
(correctness); on TPU set ``interpret=False`` via `PALLAS_INTERPRET=0`
(knob reference: DESIGN.md section 4).

Tile-window semantics: each Morton-contiguous query tile gathers ONE shared
cell window (the union of its members' windows) — that is the coherence
payoff of the paper's section-4 scheduling: neighbors of adjacent queries
come from the same VMEM-resident candidate tile.

Single-program schedule (DESIGN.md section 3): the whole
anchor→gather→distance→top-K pipeline is traced JAX — no host metadata in
the loop. Window *shapes* must still be static, so the data-dependent tile
spread is bounded by a host-static ladder (:func:`segment_levels`): the
launch-signature windows of ``partition.launch_signatures`` extended with
geometrically growing escalation sizes capped at the grid dims (the
whole-grid window always fits, so assignment is total). Each tile is
assigned, on device, the smallest ladder entry that covers the union of
its members' windows; per entry ONE masked :func:`~.knn_tile.knn_tile_anchored`
launch runs over the (level, Morton)-contiguous tile order, with off-level
tiles predicated off inside the kernel. Anchors are a traced per-tile
min/max reduction over the queries' cell coords, delivered to the kernel
by scalar prefetch.
"""
from __future__ import annotations

import os
from functools import lru_cache

import jax
import jax.numpy as jnp

from .distance_tile import distance_tile
from .knn_tile import knn_tile, knn_tile_anchored
from .range_tile import range_count
from .update_tile import bin_disp_tile

INTERPRET = os.environ.get("PALLAS_INTERPRET", "1") != "0"


@lru_cache(maxsize=512)
def segment_levels(
    ladder: tuple,              # ((w, skip), ...) launch signatures
    dims: tuple,                # grid dims (static)
) -> tuple:
    """Host-static Pallas launch ladder: ``((ws3, skip), ...)`` entries,
    ascending window volume.

    Base sizes come from the launch-signature ladder (``2w+1`` per
    signature — the paper's per-partition AABB widths); because a
    Morton-contiguous query *tile* spans more than one cell, a tile's
    shared window can need more than its signature's ``2w+1``, so the size
    set is extended with geometrically growing escalations capped at the
    grid dims. The final whole-grid entry always fits, which makes the
    on-device first-fit assignment total. Every size is crossed with the
    skip flags present in the signature ladder: escalating a
    skip-sphere-test tile to a larger window stays exact (the megacell
    that held >= K in-sphere points is still inside the window, so the
    streamed top-K distances are bounded by its K-th distance).
    """
    sizes = sorted({2 * int(w) + 1 for w, _ in ladder})
    dmax = max(dims)
    s = sizes[-1]
    while s < dmax:
        # jump straight to the whole-grid size once doubling would land
        # within a cell of it — two near-identical top rungs would double
        # the cost of the most expensive tier for nothing
        s = dmax if 2 * s + 1 >= dmax - 1 else 2 * s + 1
        sizes.append(s)
    skips = sorted({bool(sk) for _, sk in ladder})
    entries, seen = [], set()
    for s in sizes:
        ws = tuple(min(s, d) for d in dims)
        for sk in skips:
            if (ws, sk) not in seen:
                seen.add((ws, sk))
                entries.append((ws, sk))
    return tuple(entries)


def assign_tile_levels(
    qcells: jax.Array,          # [n_tiles, tile, 3] i32 member cell coords
    tile_levels: jax.Array,     # [n_tiles] i32 index into ``ladder``
    ladder: tuple,
    entries: tuple,             # segment_levels(ladder, dims)
    dims: tuple,
) -> tuple[jax.Array, jax.Array]:
    """Traced per-tile (launch level, window anchor) assignment.

    The anchor/spread computation that used to be host ``np`` metadata:
    per tile, the min/max cell coords of its members plus the signature
    window radius give the union window; the tile takes the smallest
    ladder entry (matching skip flag) that covers it, clamped to the grid.
    Returns ``(plevel [n_tiles], anchors [n_tiles, 3])``.
    """
    dims_a = jnp.asarray(dims, jnp.int32)
    lo = jnp.min(qcells, axis=1)                          # [n_tiles, 3]
    hi = jnp.max(qcells, axis=1)
    lvl = jnp.clip(tile_levels, 0, len(ladder) - 1)
    w_arr = jnp.asarray([int(w) for w, _ in ladder], jnp.int32)
    s_arr = jnp.asarray([bool(s) for _, s in ladder], jnp.bool_)
    tile_w = w_arr[lvl][:, None]                          # [n_tiles, 1]
    tile_skip = s_arr[lvl]
    need = jnp.minimum(hi - lo + 1 + 2 * tile_w, dims_a)  # per-axis cells

    # first fit, ascending volume; the defensive fallback mirrors
    # signature_levels: never land a no-skip tile on a skip entry (eliding
    # the r^2 filter is only sound for true megacell signatures)
    no_skip = [i for i, (_, sk) in enumerate(entries) if not sk]
    fb = no_skip[-1] if no_skip else len(entries) - 1
    plevel = jnp.full(tile_skip.shape, fb, jnp.int32)
    assigned = jnp.zeros(tile_skip.shape, bool)
    for e, (ws, sk) in enumerate(entries):
        fits = (jnp.all(need <= jnp.asarray(ws, jnp.int32), axis=-1)
                & (tile_skip == sk))
        hit = jnp.logical_not(assigned) & fits
        plevel = jnp.where(hit, jnp.int32(e), plevel)
        assigned = assigned | hit

    ws_table = jnp.asarray([ws for ws, _ in entries], jnp.int32)
    ws_tile = ws_table[plevel]                            # [n_tiles, 3]
    anchors = jnp.clip(lo - tile_w, 0, dims_a - ws_tile).astype(jnp.int32)
    return plevel, anchors


def window_search_segmented(
    grid,                 # core.types.CellGrid
    points: jax.Array,
    queries: jax.Array,   # [Nq, 3], Nq % tile == 0 (caller pads)
    spec,                 # core.types.GridSpec
    ladder: tuple,        # ((w, skip), ...) launch signatures
    tile_levels: jax.Array,   # [Nq // tile] i32 per-tile signature level
    radius: float,
    k: int,
    tile: int,
    interpret: bool | None = None,
    origin: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Level-segmented fused search: one masked kernel launch per ladder
    entry over the (level, Morton)-ordered query tiles (pure, traceable).

    Returns ``(d2 [Nq, k], idx [Nq, k], cnt [Nq])`` in the scheduled query
    order (``window_tile_search``'s convention). ``origin`` overrides the
    static spec origin for the cell lookup (sharded slabs); the kernel
    itself works purely in cell space, so only the anchor computation here
    needs the frame.
    """
    if interpret is None:
        interpret = INTERPRET
    nq = queries.shape[0]
    n_tiles = nq // tile
    assert n_tiles * tile == nq, (nq, tile)
    dims, cap = spec.dims, spec.capacity
    entries = segment_levels(tuple(ladder), tuple(dims))
    qc = spec.cell_of(queries, origin).reshape(n_tiles, tile, 3)
    plevel, anchors = assign_tile_levels(qc, tile_levels, tuple(ladder),
                                         entries, dims)
    dense_flat = grid.dense.reshape(-1)
    out_d2 = jnp.full((nq, k), jnp.inf, jnp.float32)
    out_idx = jnp.full((nq, k), -1, jnp.int32)
    for e, (ws, sk) in enumerate(entries):
        def _launch(carry, e=e, ws=ws, sk=sk):
            out_d2, out_idx = carry
            d2_e, idx_e = knn_tile_anchored(
                queries, points, dense_flat, anchors, plevel,
                level=e, ws=ws, dims=tuple(dims), cap=cap, k=k,
                r2=float(radius) ** 2, skip_test=sk, tq=tile,
                interpret=interpret)
            # off-level rows came back neutral; one select folds it in
            rows = jnp.repeat(plevel == e, tile)[:, None]
            return (jnp.where(rows, d2_e, out_d2),
                    jnp.where(rows, idx_e, out_idx))

        # most ladder entries own zero tiles on a typical query (the
        # escalation rungs exist for totality): skip their launches at
        # runtime with shapes still static. Under vmap the cond lowers to
        # select-and-execute-both — no worse than the unconditional launch
        with jax.named_scope(f"repro.launch.level{e}_w{ws}"):
            out_d2, out_idx = jax.lax.cond(
                jnp.any(plevel == e), _launch, lambda c: c,
                (out_d2, out_idx))
    cnt = jnp.sum((out_idx >= 0).astype(jnp.int32), axis=1)
    return out_d2, out_idx, cnt


def window_search_pallas(
    grid,                 # core.types.CellGrid
    points: jax.Array,
    queries: jax.Array,   # [Nq, 3]
    spec,                 # core.types.GridSpec
    w: int,
    radius: float,
    k: int,
    skip_test: bool,
    tile: int = 256,
    origin: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Drop-in fused-path counterpart of ``core.search.window_search``
    (single uniform launch signature). Pure and traceable: anchors are
    computed on device and the ``skip_test`` flag is honored by the kernel
    (sound because each query's megacell stays inside the shared tile
    window — see ``segment_levels``)."""
    nq = queries.shape[0]
    npad = (-nq) % tile
    if npad:
        # edge-replicate to the tile multiple (same padding discipline as
        # window_search and the executor's selections): padded rows repeat
        # the last real query, so they cannot distort the shared tile-window
        # anchors the way zero rows (origin cell) would
        queries = jnp.pad(queries, ((0, npad), (0, 0)), mode="edge")
    n_tiles = (nq + npad) // tile
    ladder = ((int(w), bool(skip_test)),)
    tile_levels = jnp.zeros((n_tiles,), jnp.int32)
    d2, idx, cnt = window_search_segmented(
        grid, points, queries, spec, ladder, tile_levels, radius, k, tile,
        origin=origin)
    return idx[:nq], d2[:nq], cnt[:nq]


__all__ = ["bin_disp_tile", "distance_tile", "knn_tile",
           "knn_tile_anchored", "range_count", "segment_levels",
           "assign_tile_levels", "window_search_segmented",
           "window_search_pallas", "INTERPRET"]
