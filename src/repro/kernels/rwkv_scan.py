"""Pallas TPU kernel: RWKV6 recurrence with VMEM-resident state.

The XLA scan spills the [hd, hd] f32 state to HBM every token (~2 MB/token/
layer on rwkv6-7b — the dominant memory-roofline term of the train cell,
EXPERIMENTS.md Perf iteration 4). This kernel keeps the state in VMEM
scratch for the whole sequence: HBM traffic collapses to the r/k/v/w/out
streams. The chunked-parallel XLA form (layers.py) is the differentiable
production path; this kernel is the inference/prefill fast path and the
record of what a fused TPU implementation achieves.

Layout: inputs reshaped to [B*H, S, hd]; grid = (B*H,); one grid step owns
one (batch, head) pair's full sequence. Recurrence per token:
    out_t = r_t (S + u * k_t^T v_t) ;  S <- diag(w_t) S + k_t^T v_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 out_ref, sT_ref, state, *, seq: int):
    state[...] = s0_ref[0]

    def body(t, _):
        r_t = r_ref[0, t, :][None, :]                    # [1, hd]
        k_t = k_ref[0, t, :][None, :]
        v_t = v_ref[0, t, :][None, :]
        w_t = w_ref[0, t, :][None, :]
        u = u_ref[0][None, :]
        kv = k_t.T @ v_t                                 # [hd, hd] outer
        out = jnp.dot(r_t, state[...] + u.T * kv,
                      preferred_element_type=jnp.float32)
        out_ref[0, t, :] = out[0]
        state[...] = w_t.T * state[...] + kv
        return 0

    jax.lax.fori_loop(0, seq, body, 0)
    sT_ref[0] = state[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, state0: jax.Array, *,
              interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """r/k/v/w [B, S, H, hd] f32, u [H, hd], state0 [B, H, hd, hd] f32
    -> (out [B, S, H, hd], state_T [B, H, hd, hd])."""
    b, s, h, hd = r.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    rf, kf, vf, wf = (fold(t.astype(jnp.float32)) for t in (r, k, v, w))
    uf = jnp.broadcast_to(u[None], (b, h, hd)).reshape(b * h, hd)
    s0 = state0.reshape(b * h, hd, hd).astype(jnp.float32)

    kernel = functools.partial(_rwkv_kernel, seq=s)
    out, s_t = pl.pallas_call(
        kernel,
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, hd), lambda i: (i, 0)),
            pl.BlockSpec((1, hd, hd), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((b * h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0)
    out = out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    return out, s_t.reshape(b, h, hd, hd)
