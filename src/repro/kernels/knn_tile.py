"""Pallas TPU kernel: fused gather → distance → streaming top-K selection.

This is the fused hot loop of the search (paper: BVH traversal + IS shader +
priority queue; here: candidate streaming + MXU distance + VPU selection,
DESIGN.md section 2):

  grid = (query_tiles, candidate_tiles)   # candidate axis is minor/stream
  per step:  p  = points[clip(idx_tile)]                (in-kernel gather)
             d2 = ||q||^2 + ||p||^2 - 2 q.p^T           (MXU, [TQ, TM])
             merge into running best-K held in VMEM scratch
  last step: emit [TQ, K] distances + indices

Two entry points share the loop body:

* :func:`knn_tile` — the candidate-id stream ([n_tiles, M] int32) is
  assembled by the caller (an XLA dynamic-slice gather over the dense
  grid). This is the legacy eager path.
* :func:`knn_tile_anchored` — the whole window gather moves INSIDE the
  kernel: per-tile window anchors and launch levels arrive as
  scalar-prefetch operands (``pltpu.PrefetchScalarGridSpec``), the dense
  cell grid stays resident (flattened, constant index map), and each grid
  step derives its TM candidate ids from pure index arithmetic on the
  prefetched anchor before gathering positions. Tiles whose prefetched
  level does not match the launch's level are skipped wholesale
  (``@pl.when``) — the masked per-level launch of the level-segmented
  schedule (DESIGN.md section 3), which is what lets the traced functional
  path (``core/api.py``) run the fused kernel as one compiled program with
  no host metadata in the loop.

The candidate *positions* are never materialized in HBM: the kernel
receives only the int32 candidate-id stream ([n_tiles, M], 4 B/candidate)
plus the coordinate table ([N, 8] f32, resident once), and gathers each TM
sub-tile of positions inside VMEM. The legacy layout shipped a
[n_tiles, M, 8] f32 window-position array (32 B/candidate) through HBM —
8x the traffic, duplicated across overlapping windows.

The merge uses K-pass extraction over [TQ, K + TM] with a one-hot argmin
(vectorizes on the VPU; no per-row gathers). A per-step threshold guard
(@pl.when) skips the merge entirely once no tile candidate beats any row's
current K-th best — the TPU analogue of the paper's AH-shader early ray
termination.

Lane-width discipline: every block whose minor dimension is K (the output
and best-K scratch blocks) is padded to a multiple of the 128-lane register
width, and the candidate-chunk width TM is rounded to a lane multiple, so
arbitrary K values (k=8, k=5, ...) lower cleanly on real TPU instead of
tripping Mosaic's tiling constraints. The wrappers keep the *logical* K:
pad columns ride as the _BIG/-1 neutral element through the merge (the
K-pass extraction only ever writes the first K columns) and are sliced off
before returning, so padded and unpadded results are bit-identical — the
same code path runs in interpret mode on CPU CI. The query-tile sublane
dimension TQ must be a multiple of 8 (asserted).

Deployment notes: a points table larger than VMEM must be sharded or kept
in ANY/HBM with manual DMA; on this container the kernels run in interpret
mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TQ = 256
DEFAULT_TM = 512
COORD_PAD = 8
LANE = 128               # TPU register lane width (minor-dim tile multiple)
SUBLANE = 8              # f32 sublane multiple (second-to-minor dim)
_BIG = 3.4e38            # sentinel "invalid/evicted" distance (plain float:
_NEG_I32 = -(2**31) + 1  # jnp scalars here would be captured tracer consts)


def _pad_lane(n: int) -> int:
    """Round ``n`` up to the 128-lane register width multiple."""
    return ((int(n) + LANE - 1) // LANE) * LANE


def _merge_topk(best_d2, best_idx, d2, idx, k: int):
    """Merge candidate tile (d2, idx) into running best (ascending)."""
    tq = best_d2.shape[0]
    md2 = jnp.concatenate([best_d2, d2], axis=1)          # [TQ, K+TM]
    midx = jnp.concatenate([best_idx, idx], axis=1)
    width = md2.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (tq, width), 1)
    out_d2 = jnp.full_like(best_d2, _BIG)
    out_idx = jnp.full_like(best_idx, -1)

    def body(j, carry):
        md2, out_d2, out_idx = carry
        dmin = jnp.min(md2, axis=1, keepdims=True)        # [TQ, 1]
        # first occurrence one-hot of the row min
        is_min = md2 == dmin
        first = jnp.cumsum(is_min.astype(jnp.int32), axis=1) == 1
        oh = is_min & first
        imin = jnp.max(jnp.where(oh, midx, _NEG_I32), axis=1, keepdims=True)
        col = jax.lax.broadcasted_iota(jnp.int32, out_d2.shape, 1)
        out_d2 = jnp.where(col == j, dmin, out_d2)
        out_idx = jnp.where(col == j, imin, out_idx)
        md2 = jnp.where(oh, _BIG, md2)
        return md2, out_d2, out_idx

    _, out_d2, out_idx = jax.lax.fori_loop(
        0, k, body, (md2, out_d2, out_idx))
    out_idx = jnp.where(out_d2 >= _BIG, -1, out_idx)
    return out_d2, out_idx


def _stream_candidates(q, pts, idx, best_d2, best_idx, *, k: int, r2: float,
                       skip_test: bool, n_pts: int):
    """One candidate-tile step of the streaming top-K (shared by both
    kernels): gather positions from the resident coordinate table, distance
    on the MXU, merge into the running best-K scratch behind the
    threshold guard."""
    # fused gather: candidate positions pulled from the VMEM-resident
    # coordinate table; invalid slots (-1) clip to row 0 and are masked below
    p = jnp.take(pts, jnp.clip(idx, 0, n_pts - 1), axis=0)  # [TM, 8]
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    pn = jnp.sum(p * p, axis=1)[None, :]
    cross = jnp.dot(q, p.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(qn + pn - 2.0 * cross, 0.0)          # [TQ, TM]

    invalid = jnp.broadcast_to((idx < 0)[None, :], d2.shape)
    if not skip_test:
        invalid = invalid | (d2 > r2)
    d2 = jnp.where(invalid, _BIG, d2)
    idx_b = jnp.where(invalid, -1, jnp.broadcast_to(idx[None, :], d2.shape))

    # threshold guard: does any candidate beat any row's current K-th best?
    # (only the first k columns are live — the lane-pad columns stay _BIG
    # forever and would otherwise pin the guard open)
    row_kth = jnp.max(best_d2[...][:, :k], axis=1)        # [TQ]
    row_min = jnp.min(d2, axis=1)                         # [TQ]
    beats = jnp.any(row_min < row_kth)

    @pl.when(beats)
    def _merge():
        nd2, nidx = _merge_topk(best_d2[...], best_idx[...], d2, idx_b, k)
        best_d2[...] = nd2
        best_idx[...] = nidx


def _emit_best(out_d2_ref, out_idx_ref, best_d2, best_idx):
    out_d2_ref[...] = jnp.where(best_d2[...] >= _BIG, jnp.inf, best_d2[...])
    out_idx_ref[...] = best_idx[...]


def _knn_kernel(q_ref, pts_ref, idx_ref, out_d2_ref, out_idx_ref,
                best_d2, best_idx, *, k: int, r2: float, skip_test: bool,
                n_m: int, n_pts: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_d2[...] = jnp.full_like(best_d2, _BIG)
        best_idx[...] = jnp.full_like(best_idx, -1)

    _stream_candidates(q_ref[...], pts_ref[...], idx_ref[0], best_d2,
                       best_idx, k=k, r2=r2, skip_test=skip_test,
                       n_pts=n_pts)

    @pl.when(j == n_m - 1)
    def _emit():
        _emit_best(out_d2_ref, out_idx_ref, best_d2, best_idx)


@functools.partial(
    jax.jit,
    static_argnames=("k", "r2", "skip_test", "tq", "tm", "interpret"))
def knn_tile(
    q: jax.Array,          # [Nq, 3] f32, Nq % tq == 0 per query tile group
    points: jax.Array,     # [N, 3] f32 coordinate table (gathered in-kernel)
    wnd_idx: jax.Array,    # [n_tiles, M] int32 candidate ids (-1 invalid)
    *,
    k: int,
    r2: float,
    skip_test: bool = False,
    tq: int = DEFAULT_TQ,
    tm: int = DEFAULT_TM,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Streaming top-K of each query against its tile's candidate id window.

    Returns (d2 [Nq, k] ascending inf-padded, idx [Nq, k] -1-padded).
    """
    n_tiles, m = wnd_idx.shape
    assert q.shape[0] == n_tiles * tq, (q.shape, n_tiles, tq)
    assert tq % SUBLANE == 0, f"query tile {tq} must be a multiple of 8"
    tm = _pad_lane(tm)
    kp = _pad_lane(k)        # block minor dim; logical K sliced off below
    n_pts = points.shape[0]
    m_pad = (-m) % tm
    wnd_idx = jnp.pad(wnd_idx, ((0, 0), (0, m_pad)), constant_values=-1)
    # coordinate table: coords padded to the register width, rows padded to
    # the sublane multiple; pad rows park far away (never selected: gather
    # indices are clipped to n_pts-1 and -1 slots are masked)
    n_row_pad = (-n_pts) % 8
    pts8 = jnp.pad(points.astype(jnp.float32),
                   ((0, n_row_pad), (0, COORD_PAD - 3)),
                   constant_values=0.0)
    qp = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, COORD_PAD - 3)))
    n_m = wnd_idx.shape[1] // tm

    kernel = functools.partial(_knn_kernel, k=k, r2=float(r2),
                               skip_test=bool(skip_test), n_m=n_m,
                               n_pts=n_pts)
    out_d2, out_idx = pl.pallas_call(
        kernel,
        grid=(n_tiles, n_m),
        in_specs=[
            pl.BlockSpec((tq, COORD_PAD), lambda i, j: (i, 0)),
            # full table, constant index map: stays VMEM-resident across
            # the candidate stream instead of re-fetching per step
            pl.BlockSpec((n_pts + n_row_pad, COORD_PAD), lambda i, j: (0, 0)),
            pl.BlockSpec((1, tm), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tq, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, kp), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles * tq, kp), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles * tq, kp), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, kp), jnp.float32),
            pltpu.VMEM((tq, kp), jnp.int32),
        ],
        interpret=interpret,
    )(qp, pts8, wnd_idx)
    return out_d2[:, :k], out_idx[:, :k]


def _knn_anchored_kernel(anchors_ref, levels_ref, q_ref, pts_ref, dense_ref,
                         out_d2_ref, out_idx_ref, best_d2, best_idx, *,
                         k: int, r2: float, skip_test: bool, level: int,
                         n_m: int, n_pts: int, m: int, tm: int,
                         ws: tuple, dims: tuple, cap: int):
    """Level-masked anchored variant: the window-candidate gather happens
    here, from the resident flattened dense grid, using the scalar-prefetched
    per-tile anchor. Tiles whose prefetched level != ``level`` skip both the
    gather and the merge (their output rows are written neutral at the last
    step so the caller's per-level combine is a plain select)."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    mine = levels_ref[i] == level

    @pl.when(mine & (j == 0))
    def _init():
        best_d2[...] = jnp.full_like(best_d2, _BIG)
        best_idx[...] = jnp.full_like(best_idx, -1)

    @pl.when(mine)
    def _step():
        ax = anchors_ref[i, 0]
        ay = anchors_ref[i, 1]
        az = anchors_ref[i, 2]
        # candidate ids for chunk j from pure index arithmetic on the
        # prefetched anchor: flat window position -> (window cell, slot) ->
        # global cell -> position in the flattened dense grid. The anchor is
        # pre-clipped so every window cell is in bounds; only the m..m_pad
        # tail (candidate positions past the window) needs masking.
        c = j * tm + jax.lax.broadcasted_iota(jnp.int32, (1, tm), 1)[0]
        slot = c % cap
        cell = c // cap
        iz = cell % ws[2]
        iy = (cell // ws[2]) % ws[1]
        ix = cell // (ws[2] * ws[1])
        flat = (((ax + ix) * dims[1] + (ay + iy)) * dims[2]
                + (az + iz)) * cap + slot
        n_flat = dims[0] * dims[1] * dims[2] * cap
        cand = jnp.take(dense_ref[...], jnp.clip(flat, 0, n_flat - 1))
        idx = jnp.where(c < m, cand, -1)                  # [TM]
        _stream_candidates(q_ref[...], pts_ref[...], idx, best_d2, best_idx,
                           k=k, r2=r2, skip_test=skip_test, n_pts=n_pts)

    @pl.when(mine & (j == n_m - 1))
    def _emit():
        _emit_best(out_d2_ref, out_idx_ref, best_d2, best_idx)

    @pl.when(jnp.logical_not(mine) & (j == n_m - 1))
    def _emit_neutral():
        out_d2_ref[...] = jnp.full_like(out_d2_ref, jnp.inf)
        out_idx_ref[...] = jnp.full_like(out_idx_ref, -1)


@functools.partial(
    jax.jit,
    static_argnames=("level", "ws", "dims", "cap", "k", "r2", "skip_test",
                     "tq", "tm", "interpret"))
def knn_tile_anchored(
    q: jax.Array,          # [Nq, 3] f32, Nq == n_tiles * tq
    points: jax.Array,     # [N, 3] f32 coordinate table (gathered in-kernel)
    dense_flat: jax.Array,  # [Dx*Dy*Dz*cap] i32 flattened cell grid
    anchors: jax.Array,    # [n_tiles, 3] i32 window anchors (scalar prefetch)
    levels: jax.Array,     # [n_tiles] i32 per-tile launch level
    *,
    level: int,            # this launch's level; other tiles are masked
    ws: tuple,             # (wx, wy, wz) static window size in cells
    dims: tuple,           # grid dims (static)
    cap: int,              # cell capacity (static)
    k: int,
    r2: float,
    skip_test: bool = False,
    tq: int = DEFAULT_TQ,
    tm: int = DEFAULT_TM,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One masked launch of the level-segmented schedule: every query tile
    whose ``levels`` entry equals ``level`` streams its anchored
    ``ws[0]*ws[1]*ws[2]*cap`` candidate window through the fused
    gather→distance→top-K loop; all other tiles are skipped and emit
    neutral rows (inf / -1). Fully traced — anchors and levels are device
    arrays delivered by scalar prefetch, so the caller composes under
    ``jit`` and ``vmap`` with zero host metadata.

    Returns (d2 [Nq, k] ascending inf-padded, idx [Nq, k] -1-padded).
    """
    n_tiles = anchors.shape[0]
    assert q.shape[0] == n_tiles * tq, (q.shape, n_tiles, tq)
    assert tq % SUBLANE == 0, f"query tile {tq} must be a multiple of 8"
    n_pts = points.shape[0]
    m = ws[0] * ws[1] * ws[2] * cap
    # candidate-chunk width: lane-multiple so the in-kernel iota/gather
    # vectors tile cleanly; the c < m mask already handles the tail
    tm = _pad_lane(min(tm, max(1, m)))
    kp = _pad_lane(k)        # block minor dim; logical K sliced off below
    n_m = (m + tm - 1) // tm
    n_row_pad = (-n_pts) % 8
    pts8 = jnp.pad(points.astype(jnp.float32),
                   ((0, n_row_pad), (0, COORD_PAD - 3)),
                   constant_values=0.0)
    qp = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, COORD_PAD - 3)))

    kernel = functools.partial(
        _knn_anchored_kernel, k=k, r2=float(r2), skip_test=bool(skip_test),
        level=int(level), n_m=n_m, n_pts=n_pts, m=m, tm=tm, ws=tuple(ws),
        dims=tuple(dims), cap=int(cap))
    n_flat = dense_flat.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles, n_m),
        in_specs=[
            pl.BlockSpec((tq, COORD_PAD), lambda i, j, a, l: (i, 0)),
            # coordinate table and dense grid: full blocks with constant
            # index maps — resident across the whole candidate stream
            pl.BlockSpec((n_pts + n_row_pad, COORD_PAD),
                         lambda i, j, a, l: (0, 0)),
            pl.BlockSpec((n_flat,), lambda i, j, a, l: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tq, kp), lambda i, j, a, l: (i, 0)),
            pl.BlockSpec((tq, kp), lambda i, j, a, l: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, kp), jnp.float32),
            pltpu.VMEM((tq, kp), jnp.int32),
        ],
    )
    out_d2, out_idx = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles * tq, kp), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles * tq, kp), jnp.int32),
        ],
        interpret=interpret,
    )(anchors.astype(jnp.int32), levels.astype(jnp.int32), qp, pts8,
      dense_flat)
    return out_d2[:, :k], out_idx[:, :k]
