"""Functional pytree-first core: ``build_index / query / update_index`` as
pure, traceable JAX (DESIGN.md section 8).

The host-orchestrated surfaces (``NeighborSearch``, ``SimulationSession``,
``distributed_neighbor_search``) cannot be called from inside a user's
jitted step function: their planning fetches partition metadata to the host
mid-pipeline. This module is the pure core they are shims over — the whole
search is a traceable JAX value, so

  * ``jax.jit(query)`` runs the full schedule→partition→search pipeline as
    one program with zero mid-trace host syncs;
  * ``jax.vmap(query)`` over a stacked batch of same-spec scenes IS
    multi-scene batching (the ROADMAP's "multi-session batching" item);
  * ``shard_map`` over stacked scene leaves distributes it;
  * ``lax.cond`` over ``update_index`` + ``plan_query``/``execute_plan``
    is the dynamic session's device-resident staleness branch
    (``core/dynamic.py``).

**Static-signature tracing contract.** The host executor plans
data-dependent launch groups (fetch megacell metadata, group bundles by
``(w_search, skip_test)``, pad to buckets). A traced query cannot shape
launches from data, so the traced path enumerates, host-statically, every
launch signature a query could be assigned — the megacell rings
``0..w_loop`` mapped through the paper's window sizing plus the
full-radius fallback (``partition.launch_signatures``) — sorts queries by
``(signature level, Morton)`` on device, and dispatches each query *tile*
through ``lax.switch`` to its signature's branch. Each tile pays only its
own window's gather cost (the partition win), every branch has static
shapes, and the signature set is bounded exactly like the executor's
padded-bucket signatures. The eager host-planned executor remains the
optimizing path (it additionally folds bundles by the cost model);
``SearchOpts.w_ladder`` coarsens the traced ladder explicitly.

``use_pallas`` now composes with the traced path: the fused kernel's
tile-window anchors are computed on device (a traced per-tile min/max over
the scheduled queries' cell coords, delivered to the kernel by scalar
prefetch), and the per-tile ``lax.switch`` is replaced by **level-segmented
launches** — ``schedule_by_level`` makes each ladder level's tiles a
contiguous run, and ``kernels/ops.window_search_segmented`` runs ONE
masked fused-kernel launch per level, with off-level tiles predicated off
inside the kernel (``@pl.when``). Under ``vmap`` this keeps the partition
win: a batched ``lax.switch`` lowers to execute-all-branches, while the
masked launches stream only each tile's own window. The Pallas *update*
kernel is likewise traced by ``update_index``. ``REPRO_SEGMENT_LAUNCHES=0``
falls back to the jnp ``lax.switch`` path (DESIGN.md section 4).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import os

import jax
import jax.numpy as jnp
import numpy as np

from ..reliability.errors import QueryError
from .grid import (build_cell_grid, choose_grid_spec, parked_mask,
                   update_cell_grid_traced)
from .partition import (MegacellStatics, compute_megacells, launch_signatures,
                        megacell_statics, signature_levels)
from .schedule import schedule_by_level
from .search import window_tile_search
from .types import (PARK_THRESHOLD, Array, CellGrid, GridSpec, SearchOpts,
                    SearchParams, SearchResult, UpdateStats)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class NeighborIndex:
    """The built search structure as a registered pytree.

    Spec-static aux (hashable, shared by every scene in a vmap batch):
    ``params``, ``opts``, ``statics``; the ``GridSpec`` rides in the
    ``CellGrid`` subtree's own aux. Leaves: ``points`` [N, 3], the grid
    arrays, ``anchor_points`` — the positions the current plan was
    captured at (the staleness statistic of ``update_index`` is measured
    against them; ``with_anchor`` re-anchors after a replan) — and
    ``origin``, an optional dynamic [3] override of the spec origin: the
    sharded slabs (``core/shards.py``) share ONE static spec across the
    mesh while each slab's local frame differs, so the frame must be a
    leaf, not aux (None = use the static ``spec.origin``).
    """

    params: SearchParams
    opts: SearchOpts
    statics: MegacellStatics
    points: Array
    grid: CellGrid
    anchor_points: Array
    origin: Array | None = None

    @property
    def spec(self) -> GridSpec:
        return self.grid.spec

    def with_anchor(self, anchor_points: Array) -> "NeighborIndex":
        return dataclasses.replace(self, anchor_points=anchor_points)

    def tree_flatten(self):
        return ((self.points, self.grid, self.anchor_points, self.origin),
                (self.params, self.opts, self.statics))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        params, opts, statics = aux
        points, grid, anchor, origin = leaves
        return cls(params=params, opts=opts, statics=statics,
                   points=points, grid=grid, anchor_points=anchor,
                   origin=origin)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QueryPlan:
    """A device-resident, replayable schedule∘partition plan.

    Static aux: query count ``nq``, tile size, and the launch-signature
    ``ladder`` the levels index into. Leaves: ``perm`` — the composed
    (level, Morton) permutation, edge-padded to a tile multiple (padded
    slots repeat the last scheduled query, so duplicate scatter writes are
    idempotent) — and ``tile_levels``, each tile's ``lax.switch`` branch.
    Both branches of the session's staleness ``lax.cond`` return one of
    these, which is what makes plan replay a device decision.
    """

    nq: int
    tile: int
    ladder: tuple
    perm: Array          # [Np] int32, Np % tile == 0
    tile_levels: Array   # [Np // tile] int32

    def tree_flatten(self):
        return ((self.perm, self.tile_levels),
                (self.nq, self.tile, self.ladder))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        nq, tile, ladder = aux
        perm, tile_levels = leaves
        return cls(nq=nq, tile=tile, ladder=ladder, perm=perm,
                   tile_levels=tile_levels)


# ---------------------------------------------------------------------------
# build / update
# ---------------------------------------------------------------------------

def build_index(points, params: SearchParams,
                opts: SearchOpts = SearchOpts(), *,
                spec: GridSpec | None = None,
                origin=None) -> NeighborIndex:
    """Build a :class:`NeighborIndex` over ``points`` [N, 3].

    Pure and traceable when ``spec`` is given (the grid build is a bin +
    stable-rank scatter). Without a spec the grid parameters are planned on
    the host from the concrete points (``choose_grid_spec``) — that is
    data-dependent host work, so under ``jit``/``vmap`` an explicit spec is
    required (and is what makes a batch of scenes share one trace).

    ``origin`` [3] dynamically overrides ``spec.origin`` for every cell
    lookup (build, update, and query planning) — the sharded slabs' shared
    static spec with per-slab frames. With ``opts.mask_parked`` rows parked
    at the padding sentinel are dropped from the grid entirely instead of
    binned into the clamped corner cell (which would pollute megacell
    counts near the grid's high corner).
    """
    if spec is None:
        if isinstance(points, jax.core.Tracer):
            raise TypeError(
                "build_index called under jit/vmap without a GridSpec: grid "
                "planning (choose_grid_spec) is host-side data-dependent "
                "work. Plan the spec eagerly and pass spec=...")
        # np.asarray is free for host inputs and one fetch for device
        # inputs; converting before the upload below avoids a host->device
        # ->host round-trip of the full cloud
        spec = choose_grid_spec(np.asarray(points, np.float32),
                                params.radius)
    with jax.named_scope("repro.build_index"):
        points = jnp.asarray(points, jnp.float32)
        if origin is not None:
            origin = jnp.asarray(origin, jnp.float32)
        valid = jnp.logical_not(parked_mask(points)) if opts.mask_parked \
            else None
        grid = build_cell_grid(points, spec, origin, valid)
        statics = megacell_statics(spec.cell_size, params, opts.w_max)
        return NeighborIndex(params=params, opts=opts, statics=statics,
                             points=points, grid=grid, anchor_points=points,
                             origin=origin)


def update_index(index: NeighborIndex,
                 new_points) -> tuple[NeighborIndex, UpdateStats]:
    """Re-bin moved points into the index's frozen spec (pure, traceable).

    Returns the updated index and on-device :class:`UpdateStats` —
    ``overflow`` / ``oob`` counters (nonzero means the frozen spec can no
    longer represent the scene exactly; the session's host respec fallback
    handles that) and ``max_disp2`` vs ``anchor_points`` (the staleness
    statistic). The anchor is deliberately NOT advanced: re-anchoring is
    the replan branch's job (``with_anchor``), typically under the
    session's ``lax.cond``.
    """
    with jax.named_scope("repro.update_index"):
        pts = jnp.asarray(new_points, jnp.float32)
        grid, stats, _ccoord = update_cell_grid_traced(
            index.grid, pts, index.anchor_points,
            use_pallas=index.opts.use_pallas, origin=index.origin,
            mask_parked=index.opts.mask_parked)
        return (dataclasses.replace(index, points=pts, grid=grid), stats)


# ---------------------------------------------------------------------------
# plan / execute / query
# ---------------------------------------------------------------------------

def plan_query(index: NeighborIndex, queries, *,
               margin: int = 0) -> QueryPlan:
    """Schedule + partition ``queries`` into a replayable :class:`QueryPlan`
    (pure, traceable).

    ``margin`` bakes the staleness allowance into every window (the traced
    counterpart of ``partition.inflate_plan_inputs``): windows inflate by
    ``margin`` cells clamped to the full-radius window, and the sphere-test
    skip is revoked for any window pushed past the inscribed ring — so a
    captured plan stays exact while drift remains under the session
    threshold.
    """
    with jax.named_scope("repro.plan_query"):
        queries = jnp.asarray(queries, jnp.float32)
        params, opts, statics = index.params, index.opts, index.statics
        spec = index.spec
        nq = queries.shape[0]
        tile = opts.query_tile
        partitioned = opts.partition and statics.has_megacells
        ladder = launch_signatures(statics, params, margin=margin,
                                   enabled=partitioned,
                                   w_ladder=opts.w_ladder)
        ccoord = spec.cell_of(queries, index.origin)
        if partitioned:
            w_search, skip, _rho = compute_megacells(index.grid, queries,
                                                     statics, params,
                                                     index.origin)
            if margin:
                w_search = jnp.minimum(w_search + jnp.int32(margin),
                                       jnp.int32(statics.w_full))
                skip = skip & (w_search <= statics.w_sph)
            levels = signature_levels(w_search, skip, ladder)
        else:
            levels = jnp.zeros((nq,), jnp.int32)
        perm = schedule_by_level(ccoord, levels, morton=opts.schedule)
        npad = (-nq) % tile
        # edge-replicate padding (same discipline as the executor's padded
        # selections): padded slots repeat the last scheduled query
        take = jnp.minimum(jnp.arange(nq + npad), nq - 1)
        perm_p = perm[take].astype(jnp.int32)
        tile_levels = jnp.max(levels[perm_p].reshape(-1, tile), axis=1)
        return QueryPlan(nq=nq, tile=tile, ladder=ladder, perm=perm_p,
                         tile_levels=tile_levels)


def _segment_launches() -> bool:
    """Safety valve: 0 falls the traced fused path back to the per-tile
    lax.switch jnp dispatch even when use_pallas is set (DESIGN.md
    section 4). Read at trace time (not import time), so toggling it
    after import affects every NEW trace — programs already compiled and
    cached under jit keep the path they were traced with until their
    cache is cleared or a fresh jit wrapper is made."""
    return os.environ.get("REPRO_SEGMENT_LAUNCHES", "1") != "0"


def execute_plan(index: NeighborIndex, queries,
                 plan: QueryPlan) -> SearchResult:
    """Run ``queries`` through a captured plan (pure, traceable).

    jnp path: one ``lax.map`` over query tiles; each tile dispatches
    through ``lax.switch`` to its launch signature's ``window_tile_search``
    branch — identical per-tile ops to the executor's launches, so results
    are exact. Fused path (``SearchOpts(use_pallas=True)``): the plan's
    (level, Morton)-contiguous tile order feeds the level-segmented
    Pallas schedule (``kernels/ops.window_search_segmented``) — device
    tile anchors by scalar prefetch, one masked fused-kernel launch per
    ladder level. Either way the scatter back through ``perm`` happens on
    device and the whole call is one traced program.
    """
    with jax.named_scope("repro.execute_plan"):
        return _execute_plan_scoped(index, queries, plan)


def _execute_plan_scoped(index, queries, plan):
    queries = jnp.asarray(queries, jnp.float32)
    params = index.params
    k, tile, nq = params.k, plan.tile, plan.nq
    grid, points, spec = index.grid, index.points, index.spec
    qs = queries[plan.perm]

    if index.opts.use_pallas and _segment_launches():
        from ..kernels.ops import window_search_segmented
        d2t, idxt, cntt = window_search_segmented(
            grid, points, qs, spec, plan.ladder, plan.tile_levels,
            params.radius, k, tile, origin=index.origin)
    else:
        def _branch(w, skip):
            def run(qt):
                return window_tile_search(grid, points, qt, spec, w,
                                          params.radius, k, skip,
                                          origin=index.origin)
            return run

        branches = [_branch(w, s) for (w, s) in plan.ladder]

        def one_tile(args):
            qt, lvl = args
            if len(branches) == 1:
                return branches[0](qt)
            return jax.lax.switch(jnp.clip(lvl, 0, len(branches) - 1),
                                  branches, qt)

        d2t, idxt, cntt = jax.lax.map(
            one_tile, (qs.reshape(-1, tile, 3), plan.tile_levels))
    # padded slots repeat the last real query, so duplicate writes below
    # carry identical rows and the scatter is idempotent
    out_idx = jnp.full((nq, k), -1, jnp.int32).at[plan.perm].set(
        idxt.reshape(-1, k))
    out_d2 = jnp.full((nq, k), jnp.inf, jnp.float32).at[plan.perm].set(
        d2t.reshape(-1, k))
    out_cnt = jnp.zeros((nq,), jnp.int32).at[plan.perm].set(
        cntt.reshape(-1))
    return SearchResult(indices=out_idx, distances2=out_d2, counts=out_cnt)


def _validate_enabled() -> bool:
    """`REPRO_VALIDATE=1` validates host-side query inputs inside
    ``query`` (DESIGN.md sections 4/11). Read per call, not at import."""
    return os.environ.get("REPRO_VALIDATE", "0") not in ("", "0")


def validate_queries(queries, *, lo=None, hi=None,
                     max_rows: int = 8):
    """Reject unservable query inputs with a structured
    :class:`~repro.reliability.QueryError` — the serving layer's
    graceful-degradation gate (DESIGN.md section 11).

    Checks NaN, inf, and out-of-domain rows. The default domain check
    only catches coordinates whose magnitude reaches the parked-row
    sentinel threshold (``types.PARK_THRESHOLD`` — such rows would be
    silently dropped from grids built with ``mask_parked``); explicit
    ``lo``/``hi`` bounds (per-axis or scalar) tighten it to a real
    domain. ``max_rows`` bounds the offending-row list on the error.

    Contract-preserving by construction: under tracing it is a no-op
    (tracers pass through — the jaxpr of ``query`` is identical with
    validation on or off), and device-resident arrays pass through
    unfetched (the one-host-sync contract owns the only transfer), so
    only host-side inputs — the serving admission path, eager callers —
    are actually inspected. Returns ``queries`` unchanged when clean.
    """
    if isinstance(queries, jax.core.Tracer) or isinstance(queries,
                                                          jax.Array):
        return queries
    q = np.asarray(queries, np.float32)
    nan = np.isnan(q).any(axis=-1)
    inf = np.isinf(q).any(axis=-1)
    finite = ~(nan | inf)
    oob = finite & (np.abs(q) >= PARK_THRESHOLD).any(axis=-1)
    if lo is not None:
        oob |= finite & (q < np.asarray(lo, np.float32)).any(axis=-1)
    if hi is not None:
        oob |= finite & (q > np.asarray(hi, np.float32)).any(axis=-1)
    bad = nan | inf | oob
    if bad.any():
        reasons = {}
        for name, mask in (("nan", nan), ("inf", inf), ("oob", oob)):
            n = int(mask.sum())
            if n:
                reasons[name] = n
        rows = np.flatnonzero(bad.reshape(-1))[:max_rows].tolist()
        raise QueryError(reasons, rows, int(np.prod(bad.shape)))
    return queries


def query(index: NeighborIndex, queries) -> SearchResult:
    """Pure neighbor search: ``execute_plan(plan_query(...))``.

    Traceable end-to-end — composes under ``jax.jit``, ``jax.vmap`` (stack
    same-spec scenes and batch both arguments), and ``shard_map``. Results
    are in query order and exact (knn distances/counts identical to the
    eager ``NeighborSearch.query``; range mode returns a valid bounded-K
    in-radius subset per the paper's interface).

    With ``REPRO_VALIDATE=1``, host-side ``queries`` are validated
    (:func:`validate_queries`) before upload; tracers and device arrays
    pass through untouched, so jaxprs and sync counts are unchanged.
    """
    if _validate_enabled():
        queries = validate_queries(queries)
    return execute_plan(index, queries, plan_query(index, queries))


def query_concat(index: NeighborIndex, queries_list) -> list[SearchResult]:
    """Batch-concat entry point: many requests' queries against one index
    as ONE ``plan_query`` + ``execute_plan`` launch, split back per request.

    This is the serving layer's drain contract (``repro.serve``,
    DESIGN.md section 10): B requests sharing a scene and search signature
    cost one traced program — one schedule/partition pass over the
    concatenated rows, one launch schedule, one result sync — instead of B.
    Exactness is per query: each row's launch-ladder level depends only on
    its own megacell statistics, and a knn query searched at a widened
    window (a tile it shares with a larger-window neighbor) still returns
    the identical k-nearest set, so per-request results are bitwise what
    ``query`` returns for that request alone. Pure and traceable (the
    split offsets are host-static shapes).
    """
    sizes = [q.shape[0] for q in queries_list]
    if not sizes:
        return []
    cat = jnp.concatenate(
        [jnp.asarray(q, jnp.float32) for q in queries_list], axis=0)
    res = query(index, cat)
    out, off = [], 0
    for n in sizes:
        out.append(SearchResult(indices=res.indices[off:off + n],
                                distances2=res.distances2[off:off + n],
                                counts=res.counts[off:off + n]))
        off += n
    return out


# ---------------------------------------------------------------------------
# keyed index cache (one-shot surface)
# ---------------------------------------------------------------------------

_SEARCHER_CACHE: collections.OrderedDict = collections.OrderedDict()
_SEARCHER_CACHE_MAX = 8


def cached_searcher(points, params: SearchParams,
                    opts: SearchOpts = SearchOpts()):
    """Keyed cache behind the one-shot ``neighbor_search``.

    The legacy one-shot path constructed a fresh ``NeighborSearch`` +
    executor per call, discarding every plan/compile cache each time.
    Here the searcher is cached by a value fingerprint of (points, params,
    opts), so repeated one-shot calls over the same point set — the
    benchmark/test pattern — reuse the built grid, partition plans, and
    compiled launch schedules. LRU-bounded at ``_SEARCHER_CACHE_MAX``;
    the entries pin their device grids until evicted, so memory-sensitive
    streaming callers should use :func:`searcher_cache_clear` (or build a
    ``NeighborSearch`` directly, which was always the uncached path).
    """
    from .search import NeighborSearch
    # np.asarray fetches device arrays and is free on host arrays (the
    # common one-shot case) — no gratuitous upload/download round-trip
    pts_np = np.asarray(points, np.float32)
    digest = hashlib.sha1(np.ascontiguousarray(pts_np).tobytes()).digest()
    key = (pts_np.shape, digest, params, opts)
    hit = _SEARCHER_CACHE.get(key)
    if hit is not None:
        _SEARCHER_CACHE.move_to_end(key)
        return hit
    ns = NeighborSearch(pts_np, params, opts)
    _SEARCHER_CACHE[key] = ns
    if len(_SEARCHER_CACHE) > _SEARCHER_CACHE_MAX:
        _SEARCHER_CACHE.popitem(last=False)
    return ns


def searcher_cache_stats() -> dict:
    """Size of the one-shot searcher cache (tests assert hit behavior by
    identity of the returned searcher)."""
    return {"entries": len(_SEARCHER_CACHE),
            "max_entries": _SEARCHER_CACHE_MAX}


def searcher_cache_clear() -> None:
    _SEARCHER_CACHE.clear()


__all__ = [
    "GridSpec",
    "NeighborIndex",
    "QueryError",
    "QueryPlan",
    "SearchOpts",
    "SearchParams",
    "SearchResult",
    "UpdateStats",
    "build_index",
    "cached_searcher",
    "execute_plan",
    "launch_signatures",
    "plan_query",
    "query",
    "query_concat",
    "searcher_cache_clear",
    "searcher_cache_stats",
    "update_index",
    "validate_queries",
]
