"""3-D Morton (Z-order) codes.

The paper (section 4) Morton-sorts the first-hit AABB centers to order query
groups; we Morton-sort grid-cell coordinates directly (DESIGN.md section 2:
a query's containing cell is its "first-hit AABB", available in closed form
on a uniform grid). 10 bits per axis (grids up to 1024^3) packed in uint32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_MASKS = (
    (16, jnp.uint32(0x030000FF)),
    (8, jnp.uint32(0x0300F00F)),
    (4, jnp.uint32(0x030C30C3)),
    (2, jnp.uint32(0x09249249)),
)


def _spread_bits(v: Array) -> Array:
    """Spread the low 10 bits of ``v`` so consecutive bits are 3 apart."""
    v = v.astype(jnp.uint32) & jnp.uint32(0x3FF)
    for shift, mask in _MASKS:
        v = (v | (v << shift)) & mask
    return v


def morton_encode(ccoord: Array) -> Array:
    """Morton code of integer cell coordinates ``ccoord`` [..., 3] -> uint32.

    Coordinates must be in [0, 1024). x is the lowest interleaved bit to
    match the raster convention used in the paper's figures.
    """
    x = _spread_bits(ccoord[..., 0])
    y = _spread_bits(ccoord[..., 1])
    z = _spread_bits(ccoord[..., 2])
    return x | (y << 1) | (z << 2)


def _compact_bits(v: Array) -> Array:
    v = v.astype(jnp.uint32) & jnp.uint32(0x09249249)
    v = (v ^ (v >> 2)) & jnp.uint32(0x030C30C3)
    v = (v ^ (v >> 4)) & jnp.uint32(0x0300F00F)
    v = (v ^ (v >> 8)) & jnp.uint32(0x030000FF)
    v = (v ^ (v >> 16)) & jnp.uint32(0x000003FF)
    return v


def morton_decode(code: Array) -> Array:
    """Inverse of :func:`morton_encode`; returns int32 [..., 3]."""
    x = _compact_bits(code)
    y = _compact_bits(code >> 1)
    z = _compact_bits(code >> 2)
    return jnp.stack([x, y, z], axis=-1).astype(jnp.int32)


def morton_sort_key(spec, pos: Array) -> Array:
    """uint32 sort key: Morton code of the containing cell of ``pos``."""
    return morton_encode(spec.cell_of(pos))


def morton_argsort(spec, pos: Array) -> Array:
    """Permutation that orders ``pos`` [N, 3] by cell Morton code."""
    return jnp.argsort(morton_sort_key(spec, pos))
