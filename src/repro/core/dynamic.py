"""Dynamic-scene subsystem: persistent sessions over moving points
(DESIGN.md section 7).

RTNN's target applications — SPH fluids, MD, point-cloud registration — are
*frame-stepped*: points move a little each step. The static pipeline pays
its whole cost again every frame (host `choose_grid_spec` sync, full grid
rebuild, cold plan/compile caches); the paper's Fig. 15 makes build time a
first-class cost for exactly this reason, and follow-on work (RT-kNNS
Unbound; dynamic fixed-radius RT search) centers keeping the index resident
across rounds. :class:`SimulationSession` is that steady-state path:

* **frozen spec** — the `GridSpec` is planned ONCE (with domain margin and
  capacity slack so points can drift), so every step's shapes are static
  and every compiled program stays valid across the whole run;
* **incremental update** — `grid.update_cell_grid` re-bins the moved
  points into the existing dense grid in one fused device program under a
  donated buffer, emitting on-device overflow / out-of-bounds counters and
  the max-displacement statistic; the only per-step host transfer besides
  the result sync is the one fused fetch of those scalars;
* **temporal-coherence plan reuse** — while the max displacement since the
  last replan stays below ``displacement_frac * cell_size``, the previous
  Morton schedule permutation and partition plan are replayed verbatim
  (``QueryExecutor.execute(reuse=...)``): zero host-side replanning, zero
  recompilation, straight into the cached compiled launch schedule. Reused
  windows carry a ``reuse_margin_cells`` inflation (the staleness contract,
  ``partition.inflate_plan_inputs``) so results stay exact under drift;
* **self-query fast path** — ``step(points)`` (the SPH/MD case) never
  uploads a second array and shares the update's cell assignment with the
  query schedule (``schedule.schedule_cells``);
* **respec fallback** — a nonzero overflow or out-of-bounds counter means
  the frozen grid can no longer represent the scene exactly; the session
  falls back to the (rare) host-side respec-and-rebuild: fresh spec, fresh
  grid, invalidated executor caches (``QueryExecutor.invalidate``).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from .grid import build_cell_grid, choose_grid_spec, update_cell_grid
from .partition import megacell_statics
from .search import NeighborSearch
from .types import (Array, GridSpec, SearchOpts, SearchParams, SearchResult)


@dataclasses.dataclass(frozen=True)
class SessionOpts:
    """Static knobs of a :class:`SimulationSession`.

    ``displacement_frac``  staleness threshold as a fraction of cell size:
                           the cached plan is replayed while the max
                           displacement since its capture stays below
                           ``displacement_frac * cell_size``. Must be
                           <= 0.5 for the ``reuse_margin_cells`` default to
                           keep reused plans exact (a half-cell drift moves
                           any point's cell by at most one).
    ``reuse_margin_cells`` window inflation baked into captured plans (see
                           ``partition.inflate_plan_inputs``): 2 cells
                           absorb candidate drift + the query's own cell
                           shift at the default threshold.
    ``capacity_slack``     cell-capacity headroom of the frozen spec (the
                           static path plans exactly at the observed max
                           occupancy; moving points need room to pile up).
                           Search cost scales with capacity — the default
                           absorbs the typical +1 occupancy drift without
                           inflating the candidate gather much; denser
                           pile-ups fall back to a respec.
    ``domain_margin_radii`` bounding-box padding of the frozen spec, in
                           search radii per side (= 4 cells of drift room
                           at the default cell size) so points can drift
                           without leaving the grid; escapes respec.
    ``auto_respec``        respec-and-rebuild when overflow/out-of-bounds
                           is detected (False: raise instead — for tests
                           and workloads that must never pay a respec).
    """

    displacement_frac: float = 0.45
    reuse_margin_cells: int = 2
    capacity_slack: float = 1.5
    domain_margin_radii: float = 1.0
    max_dim: int = 256
    auto_respec: bool = True


@dataclasses.dataclass
class StepReport:
    """Per-step breakdown (the session analogue of ``SearchReport``)."""

    t_update: float = 0.0      # grid update dispatch + fused stats fetch
    t_plan: float = 0.0        # replan (0.0 on fast steps)
    t_search: float = 0.0      # executor dispatch + result sync
    fast: bool = False         # replayed the cached plan
    replanned: bool = False
    respecced: bool = False
    max_disp: float = 0.0      # max displacement since plan anchor
    overflow: int = 0
    oob: int = 0


def session_grid_spec(points: np.ndarray, radius: float,
                      sopts: SessionOpts = SessionOpts()) -> GridSpec:
    """Host-side planning of a session's *frozen* grid: the static policy
    of ``choose_grid_spec`` plus drift headroom (domain margin, capacity
    slack) so the spec survives many frames of motion."""
    return choose_grid_spec(
        np.asarray(points, np.float32), radius,
        max_dim=sopts.max_dim,
        capacity_slack=sopts.capacity_slack,
        domain_margin=sopts.domain_margin_radii * float(radius),
    )


class SimulationSession:
    """Persistent neighbor search over a frame-stepped scene.

    >>> sess = SimulationSession(points, SearchParams(radius=0.1, k=8))
    >>> for _ in range(steps):
    ...     res = sess.step(points)          # self-query (SPH/MD)
    ...     points = integrate(points, res)

    ``step(points, queries)`` searches external queries instead; both forms
    return a ``SearchResult`` in query order, exact w.r.t. the *current*
    positions (oracle-identical to a fresh ``NeighborSearch``), including
    across respecs. ``stats()`` exposes the lifecycle counters the tests
    assert on (steps / fast_steps / replans / respecs / stats_fetches).
    """

    def __init__(
        self,
        points,
        params: SearchParams,
        opts: SearchOpts = SearchOpts(),
        sopts: SessionOpts = SessionOpts(),
        spec: GridSpec | None = None,
    ):
        if not opts.executor:
            raise ValueError("SimulationSession requires the executor path "
                             "(SearchOpts.executor=True)")
        # the staleness contract (inflate_plan_inputs): each of the query
        # and its candidates may shift ceil(frac) cells before a replan, so
        # the baked-in window margin must cover both or reuse loses
        # exactness silently
        if sopts.displacement_frac <= 0.0:
            raise ValueError("displacement_frac must be > 0")
        need = 2 * math.ceil(sopts.displacement_frac)
        if sopts.reuse_margin_cells < need:
            raise ValueError(
                f"reuse_margin_cells={sopts.reuse_margin_cells} cannot keep "
                f"reused plans exact at displacement_frac="
                f"{sopts.displacement_frac} (needs >= {need})")
        self.sopts = sopts
        pts = jnp.asarray(points, jnp.float32)
        pts_np = np.asarray(jax.device_get(pts))
        spec = spec or session_grid_spec(pts_np, params.radius, sopts)
        self._ns = NeighborSearch(pts_np, params, opts, spec=spec)
        self._ns.points = pts            # keep the caller's device buffer
        self._handle = None              # captured PlanHandle (plan anchor)
        self._anchor_points = pts        # positions at the last replan
        self._anchor_queries = None      # external-query anchor (if any)
        self._counters = collections.Counter()
        self.report = StepReport()

    # -- surface ------------------------------------------------------------

    @property
    def spec(self) -> GridSpec:
        return self._ns.spec

    @property
    def params(self) -> SearchParams:
        return self._ns.params

    @property
    def search(self) -> NeighborSearch:
        """The underlying (session-managed) static search object."""
        return self._ns

    def stats(self) -> dict:
        counters = dict(steps=0, fast_steps=0, replans=0, respecs=0,
                        stats_fetches=0)
        counters.update({k: int(v) for k, v in self._counters.items()})
        return {
            **counters,
            "last": dataclasses.asdict(self.report),
            "executor": self._ns.executor.stats(),
        }

    # -- lifecycle ----------------------------------------------------------

    def _respec(self, pts: Array) -> None:
        """Rare host-side fallback: the frozen grid overflowed or points
        escaped it. Replan the spec from current positions, rebuild, and
        invalidate every plan/compile cache keyed on the old geometry."""
        ns = self._ns
        pts_np = np.asarray(jax.device_get(pts))
        spec = session_grid_spec(pts_np, ns.params.radius, self.sopts)
        ns.spec = spec
        ns.points = pts
        ns.grid = build_cell_grid(pts, spec)
        ns.statics = megacell_statics(spec.cell_size, ns.params,
                                      ns.opts.w_max)
        ns.executor.invalidate()
        self._handle = None
        self._counters["respecs"] += 1

    def _replan(self, queries: Array, qcells_dev: Array | None,
                pts: Array, self_query: bool) -> None:
        """Capture a fresh schedule+partition+bundle plan anchored at the
        current positions (host work; amortized across the following fast
        steps)."""
        self._handle = self._ns.executor.capture_plan(
            queries, qcells_dev=qcells_dev,
            margin=self.sopts.reuse_margin_cells)
        self._anchor_points = pts
        self._anchor_queries = None if self_query else queries
        self._counters["replans"] += 1

    def step(self, points, queries=None) -> SearchResult:
        """Advance the session to ``points`` and search.

        ``queries=None`` (or ``queries is points``) is the self-query fast
        path: every particle queries its own neighborhood, the device
        upload and the cell assignment are shared between build and
        schedule. Results are in query order, exact for the current
        positions.
        """
        rep = StepReport()
        t0 = time.perf_counter()
        ns = self._ns
        pts = jnp.asarray(points, jnp.float32)
        self_query = queries is None or queries is points
        q = pts if self_query else jnp.asarray(queries, jnp.float32)

        # incremental update: one fused device program; anchor of the
        # displacement statistic is the plan capture, not the last frame
        anchor = (self._anchor_points
                  if pts.shape == self._anchor_points.shape else pts)
        grid, stats, ccoord = update_cell_grid(
            ns.grid, pts, anchor, use_pallas=ns.opts.use_pallas)

        fetch = [stats.overflow, stats.oob, stats.max_disp2]
        if (not self_query and self._anchor_queries is not None
                and q.shape == self._anchor_queries.shape):
            fetch.append(jnp.max(jnp.sum(
                (q - self._anchor_queries) ** 2, axis=-1)))
        fetched = [np.asarray(a) for a in jax.device_get(tuple(fetch))]
        self._counters["stats_fetches"] += 1
        overflow, oob, max_d2 = (int(fetched[0]), int(fetched[1]),
                                 float(fetched[2]))
        if len(fetched) > 3:
            max_d2 = max(max_d2, float(fetched[3]))
        rep.overflow, rep.oob = overflow, oob
        rep.max_disp = math.sqrt(max(max_d2, 0.0))

        if overflow > 0 or oob > 0:
            if not self.sopts.auto_respec:
                # the old grid's buffers were donated to the update; keep
                # the session consistent (same spec) before raising
                ns.points = pts
                ns.grid = grid
                raise RuntimeError(
                    f"frozen grid exhausted (overflow={overflow}, "
                    f"out_of_bounds={oob}) and auto_respec is disabled")
            self._respec(pts)
            rep.respecced = True
            ccoord = None                # old-spec cells are meaningless
        else:
            ns.points = pts
            ns.grid = grid
        rep.t_update = time.perf_counter() - t0

        threshold = self.sopts.displacement_frac * ns.spec.cell_size
        stale = (
            self._handle is None
            or self._handle.nq != q.shape[0]
            or pts.shape != self._anchor_points.shape
            # switching between self-query and external queries always
            # replans: the captured plan is anchored at the other set's
            # positions, which the displacement statistic does not track
            or self_query != (self._anchor_queries is None)
            or rep.max_disp > threshold
        )
        if stale:
            t0 = time.perf_counter()
            self._replan(q, ccoord if self_query else None, pts, self_query)
            rep.t_plan = time.perf_counter() - t0
            rep.replanned = True
        else:
            rep.fast = True
            self._counters["fast_steps"] += 1

        t0 = time.perf_counter()
        res = ns.executor.execute(q, reuse=self._handle)
        rep.t_search = time.perf_counter() - t0
        self._counters["steps"] += 1
        self.report = rep
        return res
