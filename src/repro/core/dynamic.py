"""Dynamic-scene subsystem: persistent sessions over moving points
(DESIGN.md sections 7-8).

RTNN's target applications — SPH fluids, MD, point-cloud registration — are
*frame-stepped*: points move a little each step. The static pipeline pays
its whole cost again every frame (host `choose_grid_spec` sync, full grid
rebuild, cold plan/compile caches); the paper's Fig. 15 makes build time a
first-class cost for exactly this reason, and follow-on work (RT-kNNS
Unbound; dynamic fixed-radius RT search) centers keeping the index resident
across rounds. :class:`SimulationSession` is that steady-state path, now a
thin shim over the functional core (``core/api.py``):

* **frozen spec** — the `GridSpec` is planned ONCE (with domain margin and
  capacity slack so points can drift), so every step's shapes are static
  and the one compiled step program stays valid across the whole run;
* **one fused step program** — ``step()`` dispatches a single jitted
  program: ``update_index`` (incremental re-bin + on-device counters and
  the max-displacement statistic) followed by the staleness branch and the
  search. No host work between update and search;
* **device-resident staleness** — the replan-vs-replay decision is
  ``lax.cond(max_disp2 > threshold^2, replan, replay)`` ON DEVICE: the
  replan branch recomputes the (level, Morton) :class:`~.api.QueryPlan`
  (with the ``reuse_margin_cells`` inflation baked in, the staleness
  contract of ``partition.inflate_plan_inputs``) and re-anchors; the
  replay branch returns the captured plan unchanged. The per-step stats
  fetch of the previous design is gone — the ONLY per-step host transfer
  is one packed flags scalar that rides the result materialization
  (it doubles as the respec guard);
* **self-query fast path** — ``step(points)`` (the SPH/MD case) never
  uploads a second array; points and queries are the same device buffer
  through the whole fused program;
* **respec fallback** — a nonzero overflow / out-of-bounds counter (bit 1
  of the flags scalar) means the frozen grid can no longer represent the
  scene exactly; the session falls back to the (rare) host-side
  respec-and-rebuild — fresh spec, fresh ``NeighborIndex``, forced replan
  — and re-executes the step so results stay exact across the respec.
  Respecs carry hysteresis: each one plans with geometrically growing
  capacity/margin headroom (``SessionOpts.respec_growth``), so adversarial
  workloads that keep exhausting the spec pay O(log frames) respecs.
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from . import api
from .grid import choose_grid_spec
from .types import (Array, GridSpec, SearchOpts, SearchParams, SearchResult)


@dataclasses.dataclass(frozen=True)
class SessionOpts:
    """Static knobs of a :class:`SimulationSession`.

    ``displacement_frac``  staleness threshold as a fraction of cell size:
                           the cached plan is replayed while the max
                           displacement since its capture stays below
                           ``displacement_frac * cell_size``. Must be
                           <= 0.5 for the ``reuse_margin_cells`` default to
                           keep reused plans exact (a half-cell drift moves
                           any point's cell by at most one).
    ``reuse_margin_cells`` window inflation baked into captured plans (see
                           ``partition.inflate_plan_inputs``): 2 cells
                           absorb candidate drift + the query's own cell
                           shift at the default threshold.
    ``capacity_slack``     cell-capacity headroom of the frozen spec (the
                           static path plans exactly at the observed max
                           occupancy; moving points need room to pile up).
                           Search cost scales with capacity — the default
                           absorbs the typical +1 occupancy drift without
                           inflating the candidate gather much; denser
                           pile-ups fall back to a respec.
    ``domain_margin_radii`` bounding-box padding of the frozen spec, in
                           search radii per side (= 4 cells of drift room
                           at the default cell size) so points can drift
                           without leaving the grid; escapes respec.
    ``auto_respec``        respec-and-rebuild when overflow/out-of-bounds
                           is detected (False: raise instead — for tests
                           and workloads that must never pay a respec).
    ``respec_growth``      respec hysteresis: every respec multiplies the
                           new spec's capacity slack AND domain margin by
                           ``respec_growth ** respecs_so_far``, so the
                           headroom grows geometrically. An adversarial
                           workload that keeps outrunning the frozen spec
                           (a constant-velocity escapee, a cell that
                           points keep piling into) then triggers O(log
                           frames) respecs instead of one per frame —
                           each respec buys exponentially more frames.
                           Set to 1.0 to disable (fixed headroom).
    ``respec_boost_max``   cap on the accumulated hysteresis multiplier:
                           capacity scales the dense grid's memory, so
                           unbounded geometric growth would trade a cheap
                           respec for an allocation failure on a
                           long-lived adversarial session. Past the cap
                           the respec cadence degrades gracefully from
                           O(log frames) back to O(frames / cap).
    ``donate_grid``        alias-safe grid-only donation of the fused step:
                           the dense-grid leaves of the index (always
                           session-owned — built fresh by build/update,
                           never aliasing caller arrays) are donated to the
                           step program so XLA updates the dense array in
                           place, while the points/anchor leaves — which CAN
                           alias caller-owned device buffers — are left
                           alone. None = auto (on everywhere except the CPU
                           backend, which ignores donation and would warn).
                           After a step the PREVIOUS index's grid buffers
                           are consumed: callers holding ``sess.index``
                           across steps on non-CPU backends should re-read
                           the property.
    """

    displacement_frac: float = 0.45
    reuse_margin_cells: int = 2
    capacity_slack: float = 1.5
    domain_margin_radii: float = 1.0
    max_dim: int = 256
    auto_respec: bool = True
    respec_growth: float = 2.0
    respec_boost_max: float = 64.0
    donate_grid: bool | None = None


@dataclasses.dataclass
class StepReport:
    """Per-step breakdown (the session analogue of ``SearchReport``).

    The staleness statistic lives on device but rides the packed telemetry
    vector (obs/device.py), so ``max_disp`` / ``overflow`` / ``oob`` are
    populated every step at no extra sync; ``t_update``/``t_plan`` are 0.0
    because update, plan, and search are one fused program timed as
    ``t_search``.
    """

    t_update: float = 0.0      # merged into t_search (fused step program)
    t_plan: float = 0.0        # merged into t_search (fused step program)
    t_search: float = 0.0      # fused step dispatch + telemetry/result sync
    fast: bool = False         # replayed the captured plan (device decision)
    replanned: bool = False
    respecced: bool = False
    max_disp: float = 0.0      # from the packed telemetry vector
    overflow: int = 0
    oob: int = 0


def validate_session_opts(sopts: SessionOpts) -> None:
    """The staleness-contract invariant shared by every session surface
    (`SimulationSession`, `core/shards.ShardedSession`): each of the query
    and its candidates may shift ceil(frac) cells before a replan, so the
    baked-in window margin must cover both or plan reuse silently loses
    exactness."""
    if sopts.displacement_frac <= 0.0:
        raise ValueError("displacement_frac must be > 0")
    need = 2 * math.ceil(sopts.displacement_frac)
    if sopts.reuse_margin_cells < need:
        raise ValueError(
            f"reuse_margin_cells={sopts.reuse_margin_cells} cannot keep "
            f"reused plans exact at displacement_frac="
            f"{sopts.displacement_frac} (needs >= {need})")


def session_grid_spec(points: np.ndarray, radius: float,
                      sopts: SessionOpts = SessionOpts(),
                      boost: float = 1.0) -> GridSpec:
    """Host-side planning of a session's *frozen* grid: the static policy
    of ``choose_grid_spec`` plus drift headroom (domain margin, capacity
    slack) so the spec survives many frames of motion.

    ``boost`` scales both headroom knobs — the respec-hysteresis factor
    (``respec_growth ** respecs``) the session passes on each respec so
    repeated exhaustion buys geometrically growing headroom."""
    return choose_grid_spec(
        np.asarray(points, np.float32), radius,
        max_dim=sopts.max_dim,
        capacity_slack=sopts.capacity_slack * boost,
        domain_margin=sopts.domain_margin_radii * float(radius) * boost,
    )


# ---------------------------------------------------------------------------
# the fused step program
# ---------------------------------------------------------------------------

# flags bitmask in slot 0 of the packed telemetry vector returned by the
# fused step (ONE packed int32 vector is the only per-step host transfer;
# fetching it doubles as the result sync — obs/device.py lays out the
# remaining slots: overflow, oob, displacement bits, migration, halo, and
# the per-ladder-level occupancy histogram)
_FLAG_REPLANNED = 1     # staleness cond took the replan branch
_FLAG_EXHAUSTED = 2     # overflow/oob: frozen spec can no longer bin exactly


def _step_impl(grid, index_rest: api.NeighborIndex, plan, pts: Array,
               q: Array, anchor_q: Array, *, thr2: float, margin: int,
               force: bool, self_query: bool):
    """update_index -> lax.cond(stale, replan, replay) -> execute_plan.

    Everything device-resident: the staleness statistic (max displacement
    vs the plan anchor, plus query drift in external-query mode) is
    compared against the threshold on device, and both the fresh and the
    replayed :class:`~.api.QueryPlan` flow into the same compiled search.
    ``force`` (static) is the plan-capture variant: first step, shape or
    query-set changes, and the post-respec re-execution.

    The index arrives SPLIT: ``grid`` (argument 0) carries the dense-grid
    leaves so they can be donated on their own — they are session-owned by
    construction, unlike ``index_rest``'s points/anchor leaves, which can
    alias caller buffers (and, after a replan, each other) and must never
    be donated.
    """
    index = dataclasses.replace(index_rest, grid=grid)
    index2, stats = api.update_index(index, pts)
    bad = (stats.overflow > 0) | (stats.oob > 0)
    disp2 = stats.max_disp2
    if not self_query:
        disp2 = jnp.maximum(
            disp2, jnp.max(jnp.sum((q - anchor_q) ** 2, axis=-1)))

    if force:
        stale = jnp.bool_(True)
        plan2 = api.plan_query(index2, q, margin=margin)
        anchor2, anchor_q2 = pts, q
    else:
        stale = disp2 > jnp.float32(thr2)

        def replan(_):
            return api.plan_query(index2, q, margin=margin), pts, q

        def replay(_):
            return plan, index2.anchor_points, anchor_q

        plan2, anchor2, anchor_q2 = jax.lax.cond(stale, replan, replay, None)

    index3 = index2.with_anchor(anchor2)
    res = api.execute_plan(index3, q, plan2)
    flags = (stale.astype(jnp.int32) * _FLAG_REPLANNED
             + bad.astype(jnp.int32) * _FLAG_EXHAUSTED)
    # widen the flags scalar into the packed telemetry vector: still ONE
    # per-step transfer (obs/device.py), computed unconditionally so the
    # step jaxpr is identical with host-side telemetry on or off
    telem = obs.pack_step_telemetry(
        flags, overflow=stats.overflow, oob=stats.oob, max_disp2=disp2,
        occupancy=obs.level_occupancy(plan2.tile_levels,
                                      len(plan2.ladder)))
    return index3, plan2, anchor_q2, res, telem, stats


# NOTE: the step donates ONLY the grid argument (argument 0, the dense-grid
# leaves split out of the index). The points/anchor_points leaves can alias
# caller-owned arrays (build_index keeps the caller's device buffer), and
# after a replan both leaves can be the SAME buffer — donating them would
# invalidate caller arrays off-CPU and trip duplicate-donation. The grid
# leaves, by contrast, are always freshly built by build_cell_grid /
# update_cell_grid and owned by the session, so their donation is
# alias-safe (SessionOpts.donate_grid; auto-disabled on the CPU backend,
# which ignores donation).
_STEP_STATICS = ("thr2", "margin", "force", "self_query")


class SimulationSession:
    """Persistent neighbor search over a frame-stepped scene.

    >>> sess = SimulationSession(points, SearchParams(radius=0.1, k=8))
    >>> for _ in range(steps):
    ...     res = sess.step(points)          # self-query (SPH/MD)
    ...     points = integrate(points, res)

    ``step(points, queries)`` searches external queries instead; both forms
    return a ``SearchResult`` in query order, exact w.r.t. the *current*
    positions (oracle-identical to a fresh ``NeighborSearch``), including
    across respecs. ``stats()`` exposes the lifecycle counters the tests
    assert on (steps / fast_steps / replans / respecs / stats_fetches —
    the latter stays 0 on every non-respec step).
    """

    def __init__(
        self,
        points,
        params: SearchParams,
        opts: SearchOpts = SearchOpts(),
        sopts: SessionOpts = SessionOpts(),
        spec: GridSpec | None = None,
    ):
        validate_session_opts(sopts)
        self.sopts = sopts
        pts = jnp.asarray(points, jnp.float32)
        spec = spec or session_grid_spec(
            np.asarray(jax.device_get(pts)), params.radius, sopts)
        self._index = api.build_index(pts, params, opts, spec=spec)
        self._plan: api.QueryPlan | None = None
        self._anchor_queries: Array | None = None
        donate = sopts.donate_grid
        if donate is None:
            donate = jax.default_backend() != "cpu"
        # per-session jit so a respec can release the step variants
        # compiled against the old spec (and session teardown frees them
        # all) instead of pinning them in a module-global cache forever
        self._step_fn = jax.jit(_step_impl, static_argnames=_STEP_STATICS,
                                donate_argnums=(0,) if donate else ())
        # lifecycle counters + step-latency histogram in the unified
        # registry (repro.obs)
        self._metrics = obs.metric_set("session")
        self.report = StepReport()

    # -- surface ------------------------------------------------------------

    @property
    def spec(self) -> GridSpec:
        return self._index.spec

    @property
    def params(self) -> SearchParams:
        return self._index.params

    @property
    def index(self) -> api.NeighborIndex:
        """The session-managed functional index (``core/api.py``)."""
        return self._index

    def stats(self) -> dict:
        counters = dict(steps=0, fast_steps=0, replans=0, respecs=0,
                        stats_fetches=0, host_syncs=0)
        counters.update(self._metrics.counters())
        return {
            **counters,
            "last": dataclasses.asdict(self.report),
            "step_cache_size": int(self._step_fn._cache_size()),
        }

    # -- lifecycle ----------------------------------------------------------

    def _dispatch(self, index, pts, q, anchor_q, force, self_query):
        thr2 = float((self.sopts.displacement_frac *
                      index.spec.cell_size) ** 2)
        # grid split out as its own (donatable) argument; the rest of the
        # index rides with grid=None (an empty pytree slot)
        return self._step_fn(
            index.grid, dataclasses.replace(index, grid=None),
            None if force else self._plan, pts, q, anchor_q,
            thr2=thr2, margin=int(self.sopts.reuse_margin_cells),
            force=bool(force), self_query=bool(self_query))

    def _dispatch_synced(self, index, pts, q, anchor_q, force, self_query):
        """Launch the fused step, then fetch the packed telemetry vector —
        still the session's ONE blocking transfer per step. A jit compile
        is detected from step-cache growth and recorded as a compile span
        nested under the launch."""
        cache0 = int(self._step_fn._cache_size())
        with obs.span("launch", forced=bool(force)):
            t0 = time.perf_counter()
            out = self._dispatch(index, pts, q, anchor_q, force, self_query)
            if int(self._step_fn._cache_size()) > cache0:
                obs.record_span("compile", time.perf_counter() - t0)
        with obs.span("sync"):
            telem = obs.unpack_step_telemetry(
                np.asarray(jax.device_get(out[4])))
        self._metrics.count("host_syncs")
        return out, telem

    def step(self, points, queries=None) -> SearchResult:
        """Advance the session to ``points`` and search.

        ``queries=None`` (or ``queries is points``) is the self-query fast
        path: every particle queries its own neighborhood over the shared
        device buffer. Results are in query order, exact for the current
        positions. One fused device program per step; one packed flags
        scalar is the only host transfer (it materializes the results).
        """
        rep = StepReport()
        m = self._metrics
        with obs.span("step") as sp_step:
            pts = jnp.asarray(points, jnp.float32)
            self_query = queries is None or queries is points
            q = pts if self_query else jnp.asarray(queries, jnp.float32)

            with obs.span("plan"):
                index = self._index
                if pts.shape != index.points.shape:
                    # particle count changed under the frozen spec: re-seat
                    # the leaves; the displacement statistic restarts here
                    index = dataclasses.replace(index, points=pts,
                                                anchor_points=pts)
                    self._plan = None

                anchor_q = self._anchor_queries
                # switching between self-query and external queries always
                # replans: the captured plan is anchored at the other set's
                # positions, which the displacement statistic does not track
                force = (self._plan is None
                         or self._plan.nq != q.shape[0]
                         or self_query != (anchor_q is None))
                if self_query:
                    anchor_q = q
                elif anchor_q is None or anchor_q.shape != q.shape:
                    anchor_q = q
                    force = True

            out, tel = self._dispatch_synced(index, pts, q, anchor_q,
                                             force, self_query)
            index3, plan2, anchor_q2, res, _telem, _stats = out
            fl = tel["flags"]

            if fl & _FLAG_EXHAUSTED:
                # rare path: the packed telemetry already carries the
                # counters (no extra stats fetch — stats_fetches stays 0
                # even here); respec-and-rebuild on the host and re-execute
                # so results stay exact
                rep.overflow, rep.oob = tel["overflow"], tel["oob"]
                rep.max_disp = math.sqrt(max(tel["max_disp2"], 0.0))
                if not self.sopts.auto_respec:
                    # keep the session consistent (updated grid, dropped
                    # plan) before raising
                    self._index = index3
                    self._plan = None
                    self._anchor_queries = None if self_query else anchor_q2
                    raise RuntimeError(
                        f"frozen grid exhausted (overflow={rep.overflow}, "
                        f"out_of_bounds={rep.oob}) and auto_respec is "
                        f"disabled")
                # respec hysteresis: each respec plans with geometrically
                # more capacity/margin headroom, so an adversarial pile-up
                # or escapee costs O(log frames) respecs, not one per frame
                respecs = m.count("respecs")
                boost = min(
                    float(self.sopts.respec_growth) ** int(respecs),
                    float(self.sopts.respec_boost_max))
                spec = session_grid_spec(
                    np.asarray(jax.device_get(pts)), index.params.radius,
                    self.sopts, boost=boost)
                index = api.build_index(pts, index.params, index.opts,
                                        spec=spec)
                # release every step variant compiled against the old spec
                # (the new-spec trace replaces them; the analogue of the
                # executor path's invalidate())
                self._step_fn.clear_cache()
                rep.respecced = True
                out, tel = self._dispatch_synced(index, pts, q, anchor_q,
                                                 True, self_query)
                index3, plan2, anchor_q2, res, _telem, _stats = out
                fl = tel["flags"]
                if fl & _FLAG_EXHAUSTED:        # pragma: no cover
                    raise RuntimeError(
                        f"respec failed to absorb the scene (overflow="
                        f"{tel['overflow']}, oob={tel['oob']})")

            self._index = index3
            self._plan = plan2
            self._anchor_queries = None if self_query else anchor_q2
            if not rep.respecced:
                # (the respec path keeps the PRE-respec counters: the
                # post-respec re-execution is clean by construction)
                rep.overflow, rep.oob = tel["overflow"], tel["oob"]
                rep.max_disp = math.sqrt(max(tel["max_disp2"], 0.0))
            if fl & _FLAG_REPLANNED:
                rep.replanned = True
                m.count("replans")
            else:
                rep.fast = True
                m.count("fast_steps")
            m.count("steps")
            m.count("overflow_points", tel["overflow"])
            m.count("oob_points", tel["oob"])
            for lvl, occ in enumerate(tel["occupancy"]):
                m.count(f"level_occ_{lvl}", occ)
            m.gauge("staleness_disp2", tel["max_disp2"])
            m.gauge("step_cache_size", int(self._step_fn._cache_size()))
        rep.t_search = sp_step.duration
        m.observe("step_s", rep.t_search)
        self.report = rep
        return res
