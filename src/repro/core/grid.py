"""Uniform cell grid build — the TPU-native acceleration structure.

Replaces the paper's BVH build (which on the GPU is opaque, linear in the
number of AABBs, Fig. 15). Our build is a bin + scatter, also linear in N,
and — like the paper's per-partition BVHs — can be *re-fitted* with a
partition-specific cell size (see partition.py / bundle.py) to shrink the
candidate window quantization overfetch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .types import (PARK_THRESHOLD, Array, CellGrid, GridSpec, UpdateStats)


def parked_mask(points: Array) -> Array:
    """Rows parked at the slab-padding sentinel (``types.PARK_SENTINEL``):
    any coordinate with magnitude >= ``PARK_THRESHOLD`` marks the row as an
    empty fixed-capacity slot, not a point (core/shards.py)."""
    return jnp.any(jnp.abs(points) >= jnp.float32(PARK_THRESHOLD), axis=-1)


def choose_grid_spec(
    points: np.ndarray,
    radius: float,
    *,
    cell_size: float | None = None,
    max_dim: int = 256,
    capacity: int | None = None,
    capacity_slack: float = 1.0,
    domain_margin: float = 0.0,
) -> GridSpec:
    """Host-side planning of the static grid parameters.

    Mirrors the paper's "smallest cell size allowed by the GPU memory
    capacity" policy (section 5.1): default cell edge = search radius (so the
    full-radius window is 3^3 cells), refined down while the dense array stays
    within ``max_dim`` per axis. ``capacity`` is the max cell occupancy, read
    from the data exactly like JAX-MD capacity planning; the build reports
    overflow if exceeded (asserted zero in tests).

    ``domain_margin`` pads the bounding box by that many world units on every
    side before sizing — dynamic scenes (``core/dynamic.py``) use it so points
    can drift without leaving the frozen grid. Degenerate extents (identical
    or coplanar point sets) are clamped to ``radius`` per axis so cells never
    collapse to zero size and dims stay finite.
    """
    points = np.asarray(points, dtype=np.float32)
    lo = points.min(axis=0) - domain_margin
    hi = points.max(axis=0) + domain_margin
    extent = np.maximum(hi - lo, max(float(radius), 1e-6))
    if cell_size is None:
        # cells finer than the radius (paper: smallest cell size memory
        # allows) so megacells exist: w_sph >= 1 needs cell <= r/(2*sqrt(3))
        cell_size = float(max(radius / 4.0, extent.max() / max_dim))
    # pad the domain by one cell on each side so window clamping at the
    # boundary never loses a candidate cell
    origin = lo - cell_size
    dims = tuple(int(d) for d in np.ceil(extent / cell_size).astype(int) + 3)
    dims = tuple(min(int(d), max_dim + 3) for d in dims)
    if capacity is None:
        cc = np.floor((points - origin) / cell_size).astype(np.int64)
        cc = np.clip(cc, 0, np.asarray(dims) - 1)
        flat = (cc[:, 0] * dims[1] + cc[:, 1]) * dims[2] + cc[:, 2]
        occ = np.bincount(flat, minlength=dims[0] * dims[1] * dims[2])
        capacity = int(max(1, np.ceil(occ.max() * capacity_slack)))
    return GridSpec(
        origin=tuple(float(o) for o in origin),
        cell_size=float(cell_size),
        dims=dims,
        capacity=int(capacity),
    )


@partial(jax.jit, static_argnames=("spec",))
def build_cell_grid(points: Array, spec: GridSpec,
                    origin: Array | None = None,
                    valid: Array | None = None) -> CellGrid:
    """Bin ``points`` [N, 3] into the dense fixed-capacity cell list.

    Deterministic scatter: points are ranked within their cell by a stable
    sort over flat cell id, so the slot of each point is its rank among
    same-cell points in input order. Points beyond ``capacity`` are dropped
    and counted in ``overflow``. ``origin`` optionally overrides the static
    spec origin (distributed slabs). ``valid`` [N] optionally drops rows
    from the grid entirely — parked padding slots of the sharded slabs must
    not pollute cell counts/SAT (they would inflate megacell occupancy and
    shrink windows below exactness).
    """
    ccoord = spec.cell_of(points, origin)
    flat = spec.flat_cell(ccoord)
    if valid is not None:
        flat = jnp.where(valid, flat, spec.num_cells)   # scatter-dropped
    return _grid_from_flat(flat, points.shape[0], spec)


def _grid_from_flat(flat: Array, n: int, spec: GridSpec) -> CellGrid:
    """Dense grid + counts + SAT from precomputed flat cell ids (shared by
    the static build and the dynamic update path)."""
    order = jnp.argsort(flat, stable=True)
    flat_sorted = flat[order]
    # rank within cell = position - first position of this cell id
    first_of_cell = jnp.searchsorted(flat_sorted, flat_sorted, side="left")
    rank_sorted = (jnp.arange(n, dtype=jnp.int32)
                   - first_of_cell.astype(jnp.int32))
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)

    keep = rank < spec.capacity
    dx, dy, dz = spec.dims
    dense = jnp.full((dx * dy * dz, spec.capacity), -1, jnp.int32)
    slot = jnp.where(keep, flat * spec.capacity + rank, dx * dy * dz * spec.capacity)
    dense = (
        dense.reshape(-1)
        .at[slot]
        .set(jnp.arange(n, dtype=jnp.int32), mode="drop")
        .reshape(dx * dy * dz, spec.capacity)
    )

    # mode="drop": rows routed to the out-of-range id num_cells (invalid /
    # parked slots) contribute to no cell
    counts_full = jnp.zeros((dx * dy * dz,), jnp.int32).at[flat].add(
        1, mode="drop")
    counts = jnp.minimum(counts_full, spec.capacity).reshape(dx, dy, dz)
    overflow = jnp.sum(counts_full - jnp.minimum(counts_full, spec.capacity))

    sat = _summed_area_table(counts)
    return CellGrid(
        spec=spec,
        dense=dense.reshape(dx, dy, dz, spec.capacity),
        counts=counts,
        sat=sat,
        overflow=overflow,
    )


# ---------------------------------------------------------------------------
# dynamic-scene incremental update (core/dynamic.py; DESIGN.md section 7)
# ---------------------------------------------------------------------------

def _bin_and_stats(spec: GridSpec, points: Array, anchor_points: Array,
                   origin: Array | None = None,
                   valid: Array | None = None
                   ) -> tuple[Array, Array, Array]:
    """Unclamped binning + motion statistics (jnp path).

    Returns (ccoord [N,3] clipped, oob, max_disp2): ``oob`` counts points
    whose true cell lies outside the frozen grid (clamping them would bin
    them into a wrong border cell, losing exactness — the session respecs
    instead), ``max_disp2`` is the max squared displacement vs the positions
    the current plan was captured at (the temporal-coherence statistic).
    ``origin`` overrides the static spec origin (sharded slabs); ``valid``
    [N] excludes parked padding rows from both statistics (a parked slot is
    not out of bounds, and a parked→parked row contributes 0 displacement —
    while a row whose occupant changed blows the statistic up, which is the
    conservative replan trigger the sharded session relies on).
    """
    o = (jnp.asarray(spec.origin, points.dtype) if origin is None
         else origin.astype(points.dtype))
    c = jnp.floor((points - o) / spec.cell_size).astype(jnp.int32)
    hi = jnp.asarray([d - 1 for d in spec.dims], jnp.int32)
    escaped = jnp.any((c < 0) | (c > hi), axis=-1)
    d2 = jnp.sum((points - anchor_points) ** 2, axis=-1)
    if valid is not None:
        escaped = escaped & valid
        d2 = jnp.where(valid, d2, 0.0)
    oob = jnp.sum(escaped.astype(jnp.int32))
    return jnp.clip(c, 0, hi), oob, jnp.max(d2)


def _update_impl(grid: CellGrid, points: Array, anchor_points: Array,
                 use_pallas: bool, origin: Array | None = None,
                 mask_parked: bool = False):
    spec = grid.spec
    valid = jnp.logical_not(parked_mask(points)) if mask_parked else None
    if use_pallas:
        from ..kernels.ops import INTERPRET
        from ..kernels.update_tile import bin_disp_tile
        ccoord, oob, max_d2 = bin_disp_tile(points, anchor_points, spec,
                                            origin=origin,
                                            mask_parked=mask_parked,
                                            interpret=INTERPRET)
    else:
        ccoord, oob, max_d2 = _bin_and_stats(spec, points, anchor_points,
                                             origin, valid)
    flat = spec.flat_cell(ccoord)
    if valid is not None:
        flat = jnp.where(valid, flat, spec.num_cells)
    new = _grid_from_flat(flat, points.shape[0], spec)
    stats = UpdateStats(overflow=new.overflow, oob=oob, max_disp2=max_d2)
    return new, stats, ccoord


_update_donated = partial(jax.jit,
                          static_argnames=("use_pallas", "mask_parked"),
                          donate_argnums=(0,))(_update_impl)
_update_plain = partial(jax.jit,
                        static_argnames=("use_pallas", "mask_parked"))(
                            _update_impl)


def update_cell_grid(
    grid: CellGrid,
    points: Array,
    anchor_points: Array,
    *,
    use_pallas: bool = False,
    donate: bool | None = None,
    origin: Array | None = None,
    mask_parked: bool = False,
) -> tuple[CellGrid, UpdateStats, Array]:
    """Re-bin moved ``points`` into the *frozen* spec of ``grid``.

    One fused device program replacing the per-frame teardown/rebuild of the
    static path: binning, overflow/out-of-bounds counters, and the
    max-displacement statistic come out of a single dispatch, and the old
    grid's buffers are donated (``donate=None`` auto-enables off-CPU; the CPU
    backend ignores donation and would warn) so the dense array is updated
    in place at the XLA level rather than double-allocated.

    Returns ``(grid', stats, ccoord)`` — ``ccoord`` is the per-point cell
    assignment, shared with query scheduling on the self-query fast path
    (``schedule_cells``) so it is computed exactly once per step.
    """
    if donate is None:
        donate = jax.default_backend() != "cpu"
    fn = _update_donated if donate else _update_plain
    return fn(grid, points, anchor_points, use_pallas, origin,
              mask_parked=mask_parked)


def update_cell_grid_traced(
    grid: CellGrid,
    points: Array,
    anchor_points: Array,
    *,
    use_pallas: bool = False,
    origin: Array | None = None,
    mask_parked: bool = False,
) -> tuple[CellGrid, UpdateStats, Array]:
    """Un-jitted core of :func:`update_cell_grid`, for composition inside
    larger traced programs: the functional core's ``update_index``
    (``core/api.py``) and the session's fused ``lax.cond`` step
    (``core/dynamic.py``) inline it into their own jitted bodies, where a
    nested donating jit would be meaningless."""
    return _update_impl(grid, points, anchor_points, use_pallas, origin,
                        mask_parked)


def _summed_area_table(counts: Array) -> Array:
    """3-D inclusive summed-area table with a zero border at index 0."""
    s = jnp.cumsum(jnp.cumsum(jnp.cumsum(counts, 0), 1), 2)
    return jnp.pad(s, ((1, 0), (1, 0), (1, 0)))


def box_count(sat: Array, lo: Array, hi: Array) -> Array:
    """Number of points with cell coords in the inclusive box [lo, hi].

    ``lo``/``hi`` are int32 [..., 3]; clamping to the grid is the caller's
    job (see partition.py). Classic 8-corner inclusion-exclusion on the SAT.
    """
    x0, y0, z0 = lo[..., 0], lo[..., 1], lo[..., 2]
    x1, y1, z1 = hi[..., 0] + 1, hi[..., 1] + 1, hi[..., 2] + 1
    g = lambda a, b, c: sat[a, b, c]
    return (
        g(x1, y1, z1)
        - g(x0, y1, z1) - g(x1, y0, z1) - g(x1, y1, z0)
        + g(x0, y0, z1) + g(x0, y1, z0) + g(x1, y0, z0)
        - g(x0, y0, z0)
    )


def clamp_box(spec: GridSpec, center: Array, w) -> tuple[Array, Array]:
    """Inclusive cell box of half-width ``w`` around ``center``, clamped."""
    hi_lim = jnp.asarray([d - 1 for d in spec.dims], jnp.int32)
    lo = jnp.clip(center - w, 0, hi_lim)
    hi = jnp.clip(center + w, 0, hi_lim)
    return lo, hi
