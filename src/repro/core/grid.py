"""Uniform cell grid build — the TPU-native acceleration structure.

Replaces the paper's BVH build (which on the GPU is opaque, linear in the
number of AABBs, Fig. 15). Our build is a bin + scatter, also linear in N,
and — like the paper's per-partition BVHs — can be *re-fitted* with a
partition-specific cell size (see partition.py / bundle.py) to shrink the
candidate window quantization overfetch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .types import Array, CellGrid, GridSpec


def choose_grid_spec(
    points: np.ndarray,
    radius: float,
    *,
    cell_size: float | None = None,
    max_dim: int = 256,
    capacity: int | None = None,
    capacity_slack: float = 1.0,
) -> GridSpec:
    """Host-side planning of the static grid parameters.

    Mirrors the paper's "smallest cell size allowed by the GPU memory
    capacity" policy (section 5.1): default cell edge = search radius (so the
    full-radius window is 3^3 cells), refined down while the dense array stays
    within ``max_dim`` per axis. ``capacity`` is the max cell occupancy, read
    from the data exactly like JAX-MD capacity planning; the build reports
    overflow if exceeded (asserted zero in tests).
    """
    points = np.asarray(points, dtype=np.float32)
    lo = points.min(axis=0)
    hi = points.max(axis=0)
    extent = np.maximum(hi - lo, 1e-6)
    if cell_size is None:
        # cells finer than the radius (paper: smallest cell size memory
        # allows) so megacells exist: w_sph >= 1 needs cell <= r/(2*sqrt(3))
        cell_size = float(max(radius / 4.0, extent.max() / max_dim))
    # pad the domain by one cell on each side so window clamping at the
    # boundary never loses a candidate cell
    origin = lo - cell_size
    dims = tuple(int(d) for d in np.ceil(extent / cell_size).astype(int) + 3)
    dims = tuple(min(int(d), max_dim + 3) for d in dims)
    if capacity is None:
        cc = np.floor((points - origin) / cell_size).astype(np.int64)
        cc = np.clip(cc, 0, np.asarray(dims) - 1)
        flat = (cc[:, 0] * dims[1] + cc[:, 1]) * dims[2] + cc[:, 2]
        occ = np.bincount(flat, minlength=dims[0] * dims[1] * dims[2])
        capacity = int(max(1, np.ceil(occ.max() * capacity_slack)))
    return GridSpec(
        origin=tuple(float(o) for o in origin),
        cell_size=float(cell_size),
        dims=dims,
        capacity=int(capacity),
    )


@partial(jax.jit, static_argnames=("spec",))
def build_cell_grid(points: Array, spec: GridSpec,
                    origin: Array | None = None) -> CellGrid:
    """Bin ``points`` [N, 3] into the dense fixed-capacity cell list.

    Deterministic scatter: points are ranked within their cell by a stable
    sort over flat cell id, so the slot of each point is its rank among
    same-cell points in input order. Points beyond ``capacity`` are dropped
    and counted in ``overflow``. ``origin`` optionally overrides the static
    spec origin (distributed slabs).
    """
    n = points.shape[0]
    ccoord = spec.cell_of(points, origin)
    flat = spec.flat_cell(ccoord)

    order = jnp.argsort(flat, stable=True)
    flat_sorted = flat[order]
    # rank within cell = position - first position of this cell id
    first_of_cell = jnp.searchsorted(flat_sorted, flat_sorted, side="left")
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - first_of_cell.astype(jnp.int32)
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)

    keep = rank < spec.capacity
    dx, dy, dz = spec.dims
    dense = jnp.full((dx * dy * dz, spec.capacity), -1, jnp.int32)
    slot = jnp.where(keep, flat * spec.capacity + rank, dx * dy * dz * spec.capacity)
    dense = (
        dense.reshape(-1)
        .at[slot]
        .set(jnp.arange(n, dtype=jnp.int32), mode="drop")
        .reshape(dx * dy * dz, spec.capacity)
    )

    counts_full = jnp.zeros((dx * dy * dz,), jnp.int32).at[flat].add(1)
    counts = jnp.minimum(counts_full, spec.capacity).reshape(dx, dy, dz)
    overflow = jnp.sum(counts_full - jnp.minimum(counts_full, spec.capacity))

    sat = _summed_area_table(counts)
    return CellGrid(
        spec=spec,
        dense=dense.reshape(dx, dy, dz, spec.capacity),
        counts=counts,
        sat=sat,
        overflow=overflow,
    )


def _summed_area_table(counts: Array) -> Array:
    """3-D inclusive summed-area table with a zero border at index 0."""
    s = jnp.cumsum(jnp.cumsum(jnp.cumsum(counts, 0), 1), 2)
    return jnp.pad(s, ((1, 0), (1, 0), (1, 0)))


def box_count(sat: Array, lo: Array, hi: Array) -> Array:
    """Number of points with cell coords in the inclusive box [lo, hi].

    ``lo``/``hi`` are int32 [..., 3]; clamping to the grid is the caller's
    job (see partition.py). Classic 8-corner inclusion-exclusion on the SAT.
    """
    x0, y0, z0 = lo[..., 0], lo[..., 1], lo[..., 2]
    x1, y1, z1 = hi[..., 0] + 1, hi[..., 1] + 1, hi[..., 2] + 1
    g = lambda a, b, c: sat[a, b, c]
    return (
        g(x1, y1, z1)
        - g(x0, y1, z1) - g(x1, y0, z1) - g(x1, y1, z0)
        + g(x0, y0, z1) + g(x0, y1, z0) + g(x1, y0, z0)
        - g(x0, y0, z0)
    )


def clamp_box(spec: GridSpec, center: Array, w) -> tuple[Array, Array]:
    """Inclusive cell box of half-width ``w`` around ``center``, clamped."""
    hi_lim = jnp.asarray([d - 1 for d in spec.dims], jnp.int32)
    lo = jnp.clip(center - w, 0, hi_lim)
    hi = jnp.clip(center + w, 0, hi_lim)
    return lo, hi
