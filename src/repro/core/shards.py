"""Sharded scenes: slab-resident distributed sessions on the functional
core (DESIGN.md section 6).

The paper is a single-GPU system; its host code routes queries to one
device. This module maps the whole pipeline onto a JAX device mesh by
porting the spatial x-slab decomposition onto the pytree core
(``core/api.py``), so scale-out composes with everything the functional
core already composes with (jit, the Pallas pipeline, sessions):

* **Traced slab routing.** The legacy distributed path bucketed points and
  queries on the host (``np.digitize`` + Python loops) on EVERY call. Here
  routing is pure traced JAX — slab-of-x bucketing, a stable rank within
  each slab, and a padded scatter into fixed-capacity per-slab buffers
  (:func:`route_points` / :func:`route_queries`) — and the inverse scatter
  (:func:`unroute_results`) is traced too, so a distributed query is ONE
  compiled program with zero host-side routing.
* **One shared static spec.** Every slab uses the same static
  :class:`~.types.GridSpec`; only the frame differs per slab — a dynamic
  ``origin`` leaf on the slab's :class:`~.api.NeighborIndex`
  (``layout.origin_of(axis_index)``). A single trace therefore serves the
  whole mesh; slabs are SPMD.
* **O(surface) halo exchange.** Inside ``shard_map``, each slab sends the
  points within ``radius`` of its faces to its two spatial neighbors via
  ``jax.lax.ppermute`` (static per-face caps), then runs plain
  ``api.query`` over owned + halo points — communication scales with the
  slab surface, not the volume.
* **Parked-row convention.** Fixed-capacity buffers pad with
  ``types.PARK_SENTINEL`` positions and id -1; ``SearchOpts.mask_parked``
  makes the functional core drop parked rows from the grid (they must not
  pollute megacell counts) and from the update statistics.
* **Slab-resident stepping** (:class:`ShardedSession`). The dynamic-scene
  session of DESIGN.md section 7, per slab: frozen shared spec, per-slab
  ``api.update_index`` over the halo-extended rows, a per-slab staleness
  ``lax.cond`` replaying the captured per-slab :class:`~.api.QueryPlan`,
  and cross-boundary particle **migration** — rows whose new position left
  the slab travel to the neighbor by ``ppermute`` under a static per-face
  cap and merge into free rows. Steady-state steps perform ZERO host-side
  routing (``stats()["host_routings"]`` counts the only host routing
  events: construction and the respec-style fallback). Any cap overflow —
  migration cap, halo cap, cell capacity, out-of-bounds, a multi-slab hop
  — raises a device flag and falls back to a host re-plan/re-route with
  geometrically growing headroom (the respec hysteresis of section 7).

``distributed_neighbor_search`` (``core/distributed.py``) is now a thin
shim over :func:`shard_scene` + :meth:`ShardedIndex.query`.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

# jax >= 0.5 promotes shard_map to jax.shard_map and renames the replication
# check kwarg check_rep -> check_vma; this repo must run on both.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}

from .. import obs
from . import api
from .dynamic import SessionOpts, validate_session_opts
from .types import (PARK_SENTINEL, Array, GridSpec, SearchOpts, SearchParams,
                    SearchResult)

_FLAG_REPLANNED = 1     # some slab's staleness cond took the replan branch
_FLAG_EXHAUSTED = 2     # a cap overflowed: layout can no longer hold scene


@dataclasses.dataclass(frozen=True)
class ShardOpts:
    """Static knobs of the slab decomposition.

    The ``*_slack`` factors size the fixed-capacity per-slab buffers above
    the observed distribution so rows can migrate/drift between host
    re-plans; ``migrate_frac`` caps the per-face per-step migration volume
    (static shape of the ``ppermute`` payload). ``reroute_growth`` is the
    hysteresis of the host fallback: every re-route multiplies all
    headroom by the accumulated boost, so a workload that keeps exhausting
    the layout pays O(log frames) re-routes (mirrors
    ``SessionOpts.respec_growth``).
    """

    point_slack: float = 1.6
    halo_slack: float = 1.6
    migrate_frac: float = 0.2
    query_slack: float = 1.5
    capacity_slack: float = 1.5
    domain_margin_radii: float = 1.0
    max_dim: int = 128
    auto_reroute: bool = True
    reroute_growth: float = 2.0
    reroute_boost_max: float = 64.0


# the one-shot path (distributed_neighbor_search) decomposes a STATIC
# scene: exact caps, no drift headroom
STATIC_SCENE_OPTS = ShardOpts(point_slack=1.0, halo_slack=1.0,
                              query_slack=1.0, capacity_slack=1.0,
                              domain_margin_radii=0.0)


@dataclasses.dataclass(frozen=True)
class SlabLayout:
    """Host-planned static layout of the spatial decomposition (hashable:
    jitted programs specialize on it).

    ``spec`` is the ONE static grid spec shared by every slab;
    ``spec.origin`` is slab 0's local frame and :meth:`origin_of` shifts it
    per slab — the only per-slab quantity, and it is a traced value, which
    is what lets a single trace serve the whole mesh.
    """

    n_slabs: int
    n_qsplit: int
    lo_x: float
    slab_width: float
    halo: float             # world-units halo width (= search radius)
    point_cap: int          # owned-row slots per slab
    halo_cap: int           # per-face halo-exchange payload rows
    migrate_cap: int        # per-face per-step migration payload rows
    query_cap: int          # rows per (slab, qsplit) routing cell
    spec: GridSpec

    @property
    def total_rows(self) -> int:
        """Rows of the halo-extended per-slab point buffer."""
        return self.point_cap + 2 * self.halo_cap

    def origin_of(self, sidx: Array) -> Array:
        """Local grid origin of slab ``sidx`` (traced)."""
        ox = (jnp.float32(self.spec.origin[0])
              + sidx.astype(jnp.float32) * jnp.float32(self.slab_width))
        return jnp.stack([ox, jnp.float32(self.spec.origin[1]),
                          jnp.float32(self.spec.origin[2])])

    def slab_of(self, x: Array) -> Array:
        """Slab id of x-coordinates (traced; clipped to the edge slabs)."""
        s = jnp.floor((x - jnp.float32(self.lo_x))
                      / jnp.float32(self.slab_width)).astype(jnp.int32)
        return jnp.clip(s, 0, self.n_slabs - 1)

    def slab_bounds(self, sidx: Array) -> tuple[Array, Array]:
        lo = (jnp.float32(self.lo_x)
              + sidx.astype(jnp.float32) * jnp.float32(self.slab_width))
        return lo, lo + jnp.float32(self.slab_width)


def plan_layout(points, params: SearchParams, n_slabs: int, *,
                n_qsplit: int = 1, queries=None,
                shopts: ShardOpts = ShardOpts(),
                cell_size: float | None = None,
                boost: float = 1.0) -> SlabLayout:
    """Host-side planning of the slab decomposition (the ONLY host routing
    work; everything downstream is traced).

    Equal-width x-slabs over the (margin-padded) point extent; the shared
    local spec covers one slab + halo + the one-cell clamp pad, with cell
    capacity measured EXACTLY per slab (each slab's owned + halo points
    binned in its own frame) times the slack. ``boost`` is the re-route
    hysteresis multiplier applied to every headroom knob.
    """
    pts = np.asarray(points, np.float32)
    n = pts.shape[0]
    r = float(params.radius)
    margin = shopts.domain_margin_radii * r * boost
    lo = pts.min(axis=0) - margin
    hi = pts.max(axis=0) + margin
    lo_x = float(lo[0])
    width = max((float(hi[0]) - lo_x) / n_slabs, 1e-6)
    halo = r

    ex = width + 2.0 * halo
    ey = max(float(hi[1] - lo[1]), r)
    ez = max(float(hi[2] - lo[2]), r)
    if cell_size is not None:
        cell = float(cell_size)
    else:
        # same policy as choose_grid_spec: cells finer than the radius so
        # megacells exist, bounded by the dense-array budget per axis
        cell = float(max(r / 4.0, max(ex, ey, ez) / shopts.max_dim))
    dims = tuple(min(int(math.ceil(e / cell)) + 3, shopts.max_dim + 3)
                 for e in (ex, ey, ez))
    origin0 = (lo_x - halo - cell, float(lo[1]) - cell, float(lo[2]) - cell)

    slab = np.clip(((pts[:, 0] - np.float32(lo_x))
                    / np.float32(width)).astype(np.int64), 0, n_slabs - 1)
    p_cnt = np.bincount(slab, minlength=n_slabs)
    relx = pts[:, 0] - (lo_x + slab * width)
    # domain-edge outer faces ship nothing (no neighbor) — size the caps
    # from the interior faces only
    nb_l = np.bincount(slab[(relx <= halo) & (slab > 0)],
                       minlength=n_slabs)
    nb_r = np.bincount(slab[(width - relx <= halo)
                            & (slab < n_slabs - 1)], minlength=n_slabs)

    point_cap = int(min(n, max(8, math.ceil(
        p_cnt.max() * shopts.point_slack * boost))))
    halo_cap = int(min(n, max(1, math.ceil(
        max(nb_l.max(), nb_r.max(), 1) * shopts.halo_slack * boost))))
    migrate_cap = int(min(max(1, point_cap // 2),
                          max(8, math.ceil(point_cap
                                           * shopts.migrate_frac))))

    # exact worst-case cell occupancy across the per-slab frames (the
    # frames are shifted by slab_width, which is not a cell multiple, so a
    # global-grid estimate would not bound them)
    occ_max = 1
    dims_a = np.asarray(dims)
    for s in range(n_slabs):
        xlo = lo_x + s * width - halo
        xhi = lo_x + (s + 1) * width + halo
        sel = pts[(pts[:, 0] >= xlo) & (pts[:, 0] <= xhi)]
        if not len(sel):
            continue
        o_s = np.asarray([xlo - cell, origin0[1], origin0[2]], np.float32)
        cc = np.clip(np.floor((sel - o_s) / cell).astype(np.int64), 0,
                     dims_a - 1)
        flat = (cc[:, 0] * dims[1] + cc[:, 1]) * dims[2] + cc[:, 2]
        _u, occ = np.unique(flat, return_counts=True)
        occ_max = max(occ_max, int(occ.max()))
    capacity = int(max(1, math.ceil(
        occ_max * shopts.capacity_slack * boost)))

    if queries is not None:
        qs = np.asarray(queries, np.float32)
        q_slab = np.clip(((qs[:, 0] - np.float32(lo_x))
                          / np.float32(width)).astype(np.int64), 0,
                         n_slabs - 1)
        q_cnt = np.bincount(q_slab, minlength=n_slabs)
        query_cap = int(max(1, math.ceil(
            q_cnt.max() / n_qsplit * shopts.query_slack * boost)))
    else:
        query_cap = int(max(1, math.ceil(point_cap / n_qsplit)))

    return SlabLayout(
        n_slabs=int(n_slabs), n_qsplit=int(n_qsplit), lo_x=lo_x,
        slab_width=float(width), halo=float(halo), point_cap=point_cap,
        halo_cap=halo_cap, migrate_cap=migrate_cap, query_cap=query_cap,
        spec=GridSpec(origin=origin0, cell_size=cell, dims=dims,
                      capacity=capacity))


# ---------------------------------------------------------------------------
# traced routing (replaces the host np.digitize round-trip)
# ---------------------------------------------------------------------------

def _rank_within(key: Array, n: int) -> Array:
    """Stable rank of each element among equal keys, in input order."""
    order = jnp.argsort(key, stable=True)
    ks = key[order]
    first = jnp.searchsorted(ks, ks, side="left")
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - first.astype(jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)


def route_points(layout: SlabLayout, points: Array,
                 ids: Array | None = None
                 ) -> tuple[Array, Array, Array]:
    """Traced slab routing of ``points`` [N, 3] into fixed-capacity
    per-slab buffers.

    Returns ``(pts [S, P, 3], ids [S, P], overflow)``: parked rows carry
    the sentinel position and id -1; ``overflow`` counts points dropped
    because their slab's ``point_cap`` was exceeded (nonzero means the
    layout must be re-planned — it cannot happen when the layout was
    planned over these points).
    """
    n = points.shape[0]
    s_slabs, cap = layout.n_slabs, layout.point_cap
    gids = (jnp.arange(n, dtype=jnp.int32) if ids is None
            else ids.astype(jnp.int32))
    slab = layout.slab_of(points[:, 0])
    rank = _rank_within(slab, n)
    keep = rank < cap
    slot = jnp.where(keep, slab * cap + rank, s_slabs * cap)
    pts = (jnp.full((s_slabs * cap, 3), PARK_SENTINEL, jnp.float32)
           .at[slot].set(points.astype(jnp.float32), mode="drop")
           .reshape(s_slabs, cap, 3))
    out_ids = (jnp.full((s_slabs * cap,), -1, jnp.int32)
               .at[slot].set(gids, mode="drop").reshape(s_slabs, cap))
    return pts, out_ids, jnp.sum(jnp.logical_not(keep).astype(jnp.int32))


def route_queries(layout: SlabLayout, queries: Array
                  ) -> tuple[Array, Array, Array]:
    """Traced query routing into ``[S, C, Q, 3]`` buffers (C =
    ``n_qsplit`` round-robin columns per slab, the "model"-axis query
    split). Returns ``(qs, qid [S, C, Q], overflow)``.
    """
    nq = queries.shape[0]
    s_slabs, c, cap = layout.n_slabs, layout.n_qsplit, layout.query_cap
    slab = layout.slab_of(queries[:, 0])
    rank = _rank_within(slab, nq)
    col = rank % c
    pos = rank // c
    keep = pos < cap
    slot = jnp.where(keep, (slab * c + col) * cap + pos, s_slabs * c * cap)
    qs = (jnp.full((s_slabs * c * cap, 3), PARK_SENTINEL, jnp.float32)
          .at[slot].set(queries.astype(jnp.float32), mode="drop")
          .reshape(s_slabs, c, cap, 3))
    qid = (jnp.full((s_slabs * c * cap,), -1, jnp.int32)
           .at[slot].set(jnp.arange(nq, dtype=jnp.int32), mode="drop")
           .reshape(s_slabs, c, cap))
    return qs, qid, jnp.sum(jnp.logical_not(keep).astype(jnp.int32))


def unroute_results(qid: Array, gidx: Array, d2: Array, cnt: Array,
                    nq: int) -> tuple[Array, Array, Array]:
    """Traced inverse of the routing scatter: per-slab results back into
    original query order (rows with qid -1 — padding — are dropped)."""
    k = gidx.shape[-1]
    flat_q = qid.reshape(-1)
    safe = jnp.where(flat_q >= 0, flat_q, nq)       # nq is out of range
    oi = (jnp.full((nq, k), -1, jnp.int32)
          .at[safe].set(gidx.reshape(-1, k), mode="drop"))
    od = (jnp.full((nq, k), jnp.inf, jnp.float32)
          .at[safe].set(d2.reshape(-1, k), mode="drop"))
    oc = (jnp.zeros((nq,), jnp.int32)
          .at[safe].set(cnt.reshape(-1), mode="drop"))
    return oi, od, oc


# ---------------------------------------------------------------------------
# halo exchange + migration primitives (inside shard_map)
# ---------------------------------------------------------------------------

def _select_rows(pts: Array, ids: Array, mask: Array, cap: int
                 ) -> tuple[Array, Array, Array]:
    """First ``cap`` rows where ``mask`` (stable row order, static shape).

    Returns ``(p [cap, 3], i [cap], n_masked)`` — ``n_masked`` is the TRUE
    masked count, so the caller can flag ``n_masked > cap`` overflow
    instead of silently truncating.
    """
    order = jnp.argsort(jnp.where(mask, 0, 1), stable=True)[:cap]
    valid = mask[order]
    sel_p = jnp.where(valid[:, None], pts[order], PARK_SENTINEL)
    sel_i = jnp.where(valid, ids[order], -1)
    return sel_p, sel_i, jnp.sum(mask.astype(jnp.int32))


def _pack(p: Array, i: Array) -> Array:
    # ids shifted +1 so a zero-filled (mesh-edge) permute decodes to -1
    return jnp.concatenate([p, (i + 1)[:, None].astype(jnp.float32)],
                           axis=1)


def _unpack(buf: Array) -> tuple[Array, Array]:
    i = buf[:, 3].astype(jnp.int32) - 1
    p = jnp.where((i >= 0)[:, None], buf[:, :3], PARK_SENTINEL)
    return p, i


def _neighbor_perms(n_slabs: int):
    right = [(i, i + 1) for i in range(n_slabs - 1)]
    left = [(i + 1, i) for i in range(n_slabs - 1)]
    return right, left


def _with_halo(layout: SlabLayout, pts: Array, ids: Array, sidx: Array,
               slab_axis: str) -> tuple[Array, Array, Array]:
    """O(surface) halo exchange: each slab ships the rows within ``halo``
    of its two faces to the spatial neighbors (``ppermute``) and returns
    the halo-extended ``(all_p [P + 2H, 3], all_i [P + 2H], overflow)``.
    """
    slab_lo, slab_hi = layout.slab_bounds(sidx)
    valid = ids >= 0
    # domain-edge faces have no neighbor: nothing to ship, and points
    # piling against the domain boundary must not trip the halo cap
    has_left = sidx > 0
    has_right = sidx < layout.n_slabs - 1
    near_l = valid & (pts[:, 0] - slab_lo <= layout.halo) & has_left
    near_r = valid & (slab_hi - pts[:, 0] <= layout.halo) & has_right
    send_l_p, send_l_i, n_l = _select_rows(pts, ids, near_l,
                                           layout.halo_cap)
    send_r_p, send_r_i, n_r = _select_rows(pts, ids, near_r,
                                           layout.halo_cap)
    ovf = (jnp.maximum(n_l - layout.halo_cap, 0)
           + jnp.maximum(n_r - layout.halo_cap, 0))
    right_perm, left_perm = _neighbor_perms(layout.n_slabs)
    from_left = jax.lax.ppermute(_pack(send_r_p, send_r_i), slab_axis,
                                 right_perm)
    from_right = jax.lax.ppermute(_pack(send_l_p, send_l_i), slab_axis,
                                  left_perm)
    halo_l_p, halo_l_i = _unpack(from_left)
    halo_r_p, halo_r_i = _unpack(from_right)
    all_p = jnp.concatenate([pts, halo_l_p, halo_r_p], axis=0)
    all_i = jnp.concatenate([ids, halo_l_i, halo_r_i], axis=0)
    return all_p, all_i, ovf


def _migrate(layout: SlabLayout, pts: Array, ids: Array, sidx: Array,
             slab_axis: str) -> tuple[Array, Array, Array, Array]:
    """Cross-boundary particle migration (static per-face caps).

    Rows whose position left the slab travel to the adjacent slab via
    ``ppermute`` and merge into free rows there. Returns
    ``(pts', ids', n_migrated, overflow)`` — overflow is nonzero when a
    face cap overflowed, an arrival found no free row, or a row tried to
    hop more than one slab in a single step; all three mean the layout's
    static headroom is exhausted and trigger the host re-route fallback.
    """
    m_cap = layout.migrate_cap
    valid = ids >= 0
    tgt = layout.slab_of(pts[:, 0])
    delta = jnp.where(valid, tgt - sidx, 0)
    go_l = delta < 0
    go_r = delta > 0
    far = jnp.sum((jnp.abs(delta) > 1).astype(jnp.int32))

    send_l_p, send_l_i, n_l = _select_rows(pts, ids, go_l, m_cap)
    send_r_p, send_r_i, n_r = _select_rows(pts, ids, go_r, m_cap)
    ovf = (jnp.maximum(n_l - m_cap, 0) + jnp.maximum(n_r - m_cap, 0)
           + far)

    # vacate every mover's row (under overflow some movers are dropped —
    # the flag forces a full host re-route, so the state is discarded)
    gone = go_l | go_r
    pts1 = jnp.where(gone[:, None], PARK_SENTINEL, pts)
    ids1 = jnp.where(gone, -1, ids)

    right_perm, left_perm = _neighbor_perms(layout.n_slabs)
    from_left = jax.lax.ppermute(_pack(send_r_p, send_r_i), slab_axis,
                                 right_perm)
    from_right = jax.lax.ppermute(_pack(send_l_p, send_l_i), slab_axis,
                                  left_perm)
    in_p_l, in_i_l = _unpack(from_left)
    in_p_r, in_i_r = _unpack(from_right)
    in_p = jnp.concatenate([in_p_l, in_p_r], axis=0)        # [2M, 3]
    in_i = jnp.concatenate([in_i_l, in_i_r], axis=0)
    arriving = in_i >= 0

    # merge arrivals into the first free rows (stable order): the k-th
    # ARRIVAL (not the k-th buffer slot — right-neighbor arrivals sit in
    # the second half of the buffer) takes the k-th free row
    free = ids1 < 0
    n_free = jnp.sum(free.astype(jnp.int32))
    free_rows = jnp.argsort(jnp.where(free, 0, 1), stable=True)
    rank = jnp.cumsum(arriving.astype(jnp.int32)) - 1     # [2M]
    ok = arriving & (rank < n_free)
    # accepted arrivals target distinct free rows; everything else is
    # routed to the out-of-range row and scatter-dropped (a shared
    # in-range dummy would race accepted writes under duplicate indices)
    n_rows = ids1.shape[0]
    dest = jnp.where(ok, free_rows[jnp.clip(rank, 0, n_rows - 1)],
                     n_rows)
    ovf = ovf + jnp.sum(arriving.astype(jnp.int32)) \
        - jnp.sum(ok.astype(jnp.int32))
    pts2 = pts1.at[dest].set(in_p, mode="drop")
    ids2 = ids1.at[dest].set(in_i, mode="drop")
    n_migrated = n_l + n_r
    return pts2, ids2, n_migrated, ovf


# ---------------------------------------------------------------------------
# sharded one-shot query (ShardedIndex / shard_scene)
# ---------------------------------------------------------------------------

def _local_query_fn(layout: SlabLayout, params: SearchParams,
                    opts: SearchOpts, slab_axis: str):
    """Per-slab body of the sharded query: halo exchange -> build the
    slab's NeighborIndex on the shared spec (per-slab origin) ->
    ``api.query`` -> local row -> global id."""
    spec = layout.spec

    def local_fn(pts, ids, qs):
        pts, ids, qs = pts[0], ids[0], qs[0, 0]
        sidx = jax.lax.axis_index(slab_axis)
        origin = layout.origin_of(sidx)
        all_p, all_i, _ovf = _with_halo(layout, pts, ids, sidx, slab_axis)
        index = api.build_index(all_p, params, opts, spec=spec,
                                origin=origin)
        res = api.query(index, qs)
        gidx = jnp.where(res.indices >= 0,
                         all_i[jnp.clip(res.indices, 0)], -1)
        d2 = jnp.where(gidx >= 0, res.distances2, jnp.inf)
        cnt = jnp.sum((gidx >= 0).astype(jnp.int32), axis=-1)
        return gidx[None, None], d2[None, None], cnt[None, None]

    return local_fn


# LRU-bounded: every distinct layout (i.e. every one-shot decomposition of
# a fresh point set) compiles its own program; unbounded growth would pin
# every compiled schedule a long-lived process ever built
_QUERY_FN_CACHE: collections.OrderedDict = collections.OrderedDict()
_QUERY_FN_CACHE_MAX = 16


def make_sharded_query(mesh: Mesh, layout: SlabLayout,
                       params: SearchParams, opts: SearchOpts,
                       slab_axis: str = "data",
                       query_axis: str | None = None):
    """Jitted end-to-end sharded query program over ``mesh``:
    ``(pts [S,P,3], ids [S,P], queries [Nq,3]) -> (oi, od, oc, qovf)`` —
    traced query routing, ``shard_map(api.query)`` with halo exchange, and
    the traced inverse scatter, as ONE compiled program. Cached by
    ``(mesh, layout, params, opts, axes)``.
    """
    opts = dataclasses.replace(opts, mask_parked=True)
    key = (mesh, layout, params, opts, slab_axis, query_axis)
    hit = _QUERY_FN_CACHE.get(key)
    if hit is not None:
        _QUERY_FN_CACHE.move_to_end(key)
        return hit

    local_fn = _local_query_fn(layout, params, opts, slab_axis)
    q_spec = (P(slab_axis, query_axis) if query_axis is not None
              else P(slab_axis))
    fn = _shard_map(local_fn, mesh=mesh,
                    in_specs=(P(slab_axis), P(slab_axis), q_spec),
                    out_specs=(q_spec, q_spec, q_spec), **_SHARD_MAP_KW)

    @jax.jit
    def run(pts, ids, queries):
        qs, qid, qovf = route_queries(layout, queries)
        gidx, d2, cnt = fn(pts, ids, qs)
        oi, od, oc = unroute_results(qid, gidx, d2, cnt,
                                     queries.shape[0])
        return oi, od, oc, qovf

    _QUERY_FN_CACHE[key] = run
    if len(_QUERY_FN_CACHE) > _QUERY_FN_CACHE_MAX:
        _QUERY_FN_CACHE.popitem(last=False)
    return run


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedIndex:
    """A scene decomposed into device-resident slabs (a registered pytree:
    the routed buffers are the leaves; layout/mesh/params are aux).

    Built by :func:`shard_scene`; ``query(queries)`` runs the one-program
    sharded search (traced route -> shard_map(api.query) with halo
    exchange -> traced unroute) and returns results in query order with
    GLOBAL point indices.
    """

    layout: SlabLayout
    params: SearchParams
    opts: SearchOpts
    mesh: Mesh
    slab_axis: str
    query_axis: str | None
    pts: Array              # [S, P, 3] owned rows (sentinel-parked pads)
    ids: Array              # [S, P] global ids (-1 pads)

    def tree_flatten(self):
        return ((self.pts, self.ids),
                (self.layout, self.params, self.opts, self.mesh,
                 self.slab_axis, self.query_axis))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        layout, params, opts, mesh, slab_axis, query_axis = aux
        pts, ids = leaves
        return cls(layout=layout, params=params, opts=opts, mesh=mesh,
                   slab_axis=slab_axis, query_axis=query_axis, pts=pts,
                   ids=ids)

    def query(self, queries) -> SearchResult:
        queries = jnp.asarray(queries, jnp.float32)
        fn = make_sharded_query(self.mesh, self.layout, self.params,
                                self.opts, self.slab_axis,
                                self.query_axis)
        oi, od, oc, qovf = fn(self.pts, self.ids, queries)
        if int(qovf):
            raise RuntimeError(
                f"query routing overflowed the layout's query_cap="
                f"{self.layout.query_cap} ({int(qovf)} dropped); re-plan "
                "with shard_scene(..., queries=...) sized for this batch")
        return SearchResult(indices=oi, distances2=od, counts=oc)


def shard_scene(points, params: SearchParams, *,
                mesh: Mesh | None = None, n_slabs: int | None = None,
                opts: SearchOpts = SearchOpts(),
                shopts: ShardOpts = ShardOpts(),
                queries=None, cell_size: float | None = None,
                slab_axis: str = "data",
                query_axis: str | None = None) -> ShardedIndex:
    """Decompose a scene into device-resident slabs.

    Host work is the layout *planning* only (:func:`plan_layout`); the
    routing itself is the traced padded scatter. ``queries`` optionally
    sizes the query routing caps; ``mesh`` defaults to a 1-D slab mesh
    over all local devices (``launch.mesh.make_slab_mesh``).
    """
    if mesh is None:
        from ..launch.mesh import make_slab_mesh
        mesh = make_slab_mesh(n_slabs, axis=slab_axis)
    n_slabs = int(mesh.shape[slab_axis])
    n_qsplit = int(mesh.shape[query_axis]) if query_axis else 1
    opts = dataclasses.replace(opts, mask_parked=True)
    pts_np = np.asarray(jax.device_get(jnp.asarray(points, jnp.float32)))
    layout = plan_layout(pts_np, params, n_slabs, n_qsplit=n_qsplit,
                         queries=queries, shopts=shopts,
                         cell_size=cell_size)
    pts, ids, ovf = route_points(layout, jnp.asarray(points, jnp.float32))
    if int(ovf):        # cannot happen for a layout planned over `points`
        raise RuntimeError("slab routing overflowed its own layout")
    return ShardedIndex(layout=layout, params=params, opts=opts, mesh=mesh,
                        slab_axis=slab_axis, query_axis=query_axis,
                        pts=pts, ids=ids)


# ---------------------------------------------------------------------------
# slab-resident distributed session
# ---------------------------------------------------------------------------

def _local_init_fn(layout: SlabLayout, params: SearchParams,
                   opts: SearchOpts, margin: int, slab_axis: str):
    """Per-slab session bootstrap: halo exchange, index build on the shared
    spec, and the initial per-slab plan capture."""

    def local_fn(pts, ids):
        pts, ids = pts[0], ids[0]
        sidx = jax.lax.axis_index(slab_axis)
        origin = layout.origin_of(sidx)
        all_p, all_i, _ovf = _with_halo(layout, pts, ids, sidx, slab_axis)
        index = api.build_index(all_p, params, opts, spec=layout.spec,
                                origin=origin)
        plan = api.plan_query(index, pts, margin=margin)
        # pts/ids/mig pass THROUGH the shard_map so every piece of session
        # state carries the same NamedSharding the step program's outputs
        # will have — otherwise the second step recompiles on the sharding
        # change alone
        return jax.tree.map(lambda x: x[None],
                            (pts, ids, index, plan, jnp.int32(0)))

    return local_fn


def _local_step_fn(layout: SlabLayout, params: SearchParams,
                   opts: SearchOpts, thr2: float, margin: int,
                   slab_axis: str):
    """Per-slab body of the fused sharded step:

    gather (rows' new positions from the replicated frame, by resident
    global id — no routing) -> migrate -> halo exchange -> update_index ->
    per-slab staleness ``lax.cond`` (replan | replay) -> execute_plan ->
    global ids. Entirely device-resident; the caps raise flags instead of
    host decisions.
    """

    def local_fn(pts, ids, index, plan, mig_total, pg):
        pts, ids = pts[0], ids[0]
        index, plan = jax.tree.map(lambda x: x[0], (index, plan))
        mig_total = mig_total[0]
        sidx = jax.lax.axis_index(slab_axis)

        valid = ids >= 0
        new = jnp.where(valid[:, None], pg[jnp.clip(ids, 0)],
                        PARK_SENTINEL)
        pts2, ids2, n_mig, mig_ovf = _migrate(layout, new, ids, sidx,
                                              slab_axis)
        all_p, all_i, halo_ovf = _with_halo(layout, pts2, ids2, sidx,
                                            slab_axis)

        index2, stats = api.update_index(index, all_p)
        bad = ((stats.overflow > 0) | (stats.oob > 0) | (mig_ovf > 0)
               | (halo_ovf > 0))
        stale = stats.max_disp2 > jnp.float32(thr2)

        q = pts2                       # self-query: owned rows

        def replan(_):
            return api.plan_query(index2, q, margin=margin), all_p

        def replay(_):
            return plan, index2.anchor_points

        plan2, anchor2 = jax.lax.cond(stale, replan, replay, None)
        index3 = index2.with_anchor(anchor2)
        res = api.execute_plan(index3, q, plan2)
        gidx = jnp.where(res.indices >= 0,
                         all_i[jnp.clip(res.indices, 0)], -1)
        d2 = jnp.where(gidx >= 0, res.distances2, jnp.inf)
        cnt = jnp.sum((gidx >= 0).astype(jnp.int32), axis=-1)
        flags = (stale.astype(jnp.int32) * _FLAG_REPLANNED
                 + bad.astype(jnp.int32) * _FLAG_EXHAUSTED)
        # per-slab telemetry, split by cross-slab reduction: tel_i slot 0
        # (flags) reduces by max, the rest by sum — overflow, oob, rows
        # migrated this step, halo volume (occupied halo rows received),
        # and the per-ladder-level occupancy histogram. tel_f is the
        # max-reduced staleness statistic. step_prog reduces + packs them
        # into the ONE per-step transfer (obs/device.py).
        halo_vol = jnp.sum((all_i[pts2.shape[0]:] >= 0).astype(jnp.int32))
        occ = obs.level_occupancy(plan2.tile_levels, len(plan2.ladder))
        tel_i = jnp.concatenate([
            jnp.stack([flags, stats.overflow.astype(jnp.int32),
                       stats.oob.astype(jnp.int32),
                       n_mig.astype(jnp.int32), halo_vol]), occ])
        tel_f = stats.max_disp2.reshape(1)
        out_state = jax.tree.map(lambda x: x[None],
                                 (index3, plan2, mig_total + n_mig))
        return (pts2[None], ids2[None], *out_state, gidx[None], d2[None],
                cnt[None], tel_i[None], tel_f[None])

    return local_fn


class ShardedSession:
    """Slab-resident distributed :class:`~.dynamic.SimulationSession`.

    >>> sess = ShardedSession(points, SearchParams(radius=0.1, k=8),
    ...                       mesh=make_slab_mesh(4))
    >>> for _ in range(steps):
    ...     res = sess.step(points)          # global order, global ids
    ...     points = integrate(points, res)

    ``step(points)`` takes the frame's positions in GLOBAL id order
    [N, 3]; each slab gathers its own rows' new positions by resident id
    (a traced gather from the replicated frame — no routing), migrates
    rows across faces, halo-exchanges, incrementally re-bins its frozen
    local grid, and replays or replans its captured plan on device.
    Results are oracle-equal to a single-device session on the identical
    trajectory. The ONLY host-side routing events are construction and
    the (rare) exhausted-layout fallback — counted in
    ``stats()["host_routings"]``; steady-state steps fetch one packed
    flags scalar, nothing else.
    """

    def __init__(self, points, params: SearchParams,
                 opts: SearchOpts = SearchOpts(),
                 sopts: SessionOpts = SessionOpts(),
                 shopts: ShardOpts = ShardOpts(),
                 mesh: Mesh | None = None, n_slabs: int | None = None,
                 slab_axis: str = "data"):
        validate_session_opts(sopts)
        if mesh is None:
            from ..launch.mesh import make_slab_mesh
            mesh = make_slab_mesh(n_slabs, axis=slab_axis)
        self._mesh = mesh
        self._axis = slab_axis
        self._n_slabs = int(mesh.shape[slab_axis])
        self.params = params
        self.opts = dataclasses.replace(opts, mask_parked=True)
        self.sopts = sopts
        self.shopts = shopts
        self._boost = 1.0
        # lifecycle counters + step-latency histogram in the unified
        # registry (repro.obs)
        self._metrics = obs.metric_set("sharded_session")
        self.last_flags = 0
        self._t_last = 0.0
        pts_np = np.asarray(jax.device_get(jnp.asarray(points,
                                                       jnp.float32)))
        self._n = int(pts_np.shape[0])
        self._reroute(pts_np)

    # -- surface ------------------------------------------------------------

    @property
    def layout(self) -> SlabLayout:
        return self._layout

    @property
    def spec(self) -> GridSpec:
        return self._layout.spec

    def stats(self) -> dict:
        counters = dict(steps=0, fast_steps=0, replans=0, reroutes=0,
                        host_routings=0, host_syncs=0)
        counters.update(self._metrics.counters())
        return {
            **counters,
            "migrated": int(jnp.sum(self._mig_total)),
            "last_flags": int(self.last_flags),
            "boost": float(self._boost),
            "t_step": float(self._t_last),   # wall time of the last step
        }

    # -- lifecycle ----------------------------------------------------------

    def _reroute(self, pts_np: np.ndarray) -> None:
        """Host fallback (and bootstrap): re-plan the layout from current
        positions, re-route every row, rebuild the per-slab indexes, and
        recapture the per-slab plans. The ONLY host routing in the
        session's life — counted, and asserted zero across steady-state
        steps in the tests."""
        self._metrics.count("host_routings")
        layout = plan_layout(pts_np, self.params, self._n_slabs,
                             shopts=self.shopts, boost=self._boost)
        self._layout = layout
        margin = int(self.sopts.reuse_margin_cells)
        thr2 = float((self.sopts.displacement_frac
                      * layout.spec.cell_size) ** 2)
        pts, ids, ovf = route_points(layout, jnp.asarray(pts_np))
        if int(ovf):    # pragma: no cover — caps planned from same data
            raise RuntimeError("slab routing overflowed its own layout")

        ax = self._axis
        init_fn = _shard_map(
            _local_init_fn(layout, self.params, self.opts, margin, ax),
            mesh=self._mesh, in_specs=(P(ax), P(ax)),
            out_specs=(P(ax),) * 5, **_SHARD_MAP_KW)
        (self._pts, self._ids, self._index, self._plan,
         self._mig_total) = jax.jit(init_fn)(pts, ids)

        local = _local_step_fn(layout, self.params, self.opts, thr2,
                               margin, ax)
        step_inner = _shard_map(
            local, mesh=self._mesh,
            in_specs=(P(ax), P(ax), P(ax), P(ax), P(ax), P()),
            out_specs=(P(ax),) * 10, **_SHARD_MAP_KW)
        n = self._n

        def step_prog(pts, ids, index, plan, mig_total, pg):
            out = step_inner(pts, ids, index, plan, mig_total, pg)
            (pts2, ids2, index3, plan2, mig2, gidx, d2, cnt,
             tel_i, tel_f) = out
            # owned rows ARE the self-queries, so their global ids are the
            # routing ids and the one-shot inverse scatter applies as-is
            oi, od, oc = unroute_results(ids2, gidx, d2, cnt, n)
            # reduce the per-slab telemetry (slot-wise: flags by max, the
            # counters by sum, staleness by max) and pack the one per-step
            # transfer
            sums = jnp.sum(tel_i[:, 1:], axis=0)
            telem = obs.pack_step_telemetry(
                jnp.max(tel_i[:, 0]), overflow=sums[0], oob=sums[1],
                max_disp2=jnp.max(tel_f), occupancy=sums[4:],
                migrated=sums[2], halo=sums[3])
            return (pts2, ids2, index3, plan2, mig2, oi, od, oc, telem)

        # per-reroute jit: a re-route changes the (static) layout, so the
        # old variants are released with the old program
        self._step_fn = jax.jit(step_prog)

    def step(self, points) -> SearchResult:
        """Advance every slab to the frame ``points`` [N, 3] (global id
        order) and self-query. One fused device program; the packed
        telemetry vector (flags + device counters, obs/device.py) is the
        only per-step host transfer."""
        m = self._metrics
        with obs.span("step", slabs=self._n_slabs) as sp_step:
            pg = jnp.asarray(points, jnp.float32)
            with obs.span("plan"):
                if pg.shape != (self._n, 3):
                    # particle count changed: the layout's static caps are
                    # stale
                    self._n = int(pg.shape[0])
                    self._reroute(np.asarray(jax.device_get(pg)))
            out, tel = self._dispatch_synced(pg)
            fl = tel["flags"]

            if fl & _FLAG_EXHAUSTED:
                if not self.shopts.auto_reroute:
                    raise RuntimeError(
                        "sharded layout exhausted (migration/halo/capacity/"
                        "bounds) and auto_reroute is disabled")
                # respec-style fallback with hysteresis: geometrically more
                # headroom per re-route, so adversarial drift costs O(log
                # frames) re-routes
                m.count("reroutes")
                self._boost = min(self._boost * self.shopts.reroute_growth,
                                  self.shopts.reroute_boost_max)
                self._reroute(np.asarray(jax.device_get(pg)))
                out, tel = self._dispatch_synced(pg)
                fl = tel["flags"]
                if fl & _FLAG_EXHAUSTED:        # pragma: no cover
                    raise RuntimeError(
                        "re-route failed to absorb the scene")

            (self._pts, self._ids, self._index, self._plan,
             self._mig_total, oi, od, oc, _telem) = out
            self.last_flags = fl
            m.count("steps")
            if fl & _FLAG_REPLANNED:
                m.count("replans")
            else:
                m.count("fast_steps")
            m.count("migrated_rows", tel["migrated"])
            m.count("halo_rows", tel["halo"])
            m.count("overflow_points", tel["overflow"])
            m.count("oob_points", tel["oob"])
            for lvl, occ in enumerate(tel["occupancy"]):
                m.count(f"level_occ_{lvl}", occ)
            m.gauge("staleness_disp2", tel["max_disp2"])
            m.gauge("boost", self._boost)
        self._t_last = sp_step.duration
        m.observe("step_s", self._t_last)
        return SearchResult(indices=oi, distances2=od, counts=oc)

    def _dispatch(self, pg):
        return self._step_fn(self._pts, self._ids, self._index,
                             self._plan, self._mig_total, pg)

    def _dispatch_synced(self, pg):
        """Launch the fused sharded step and fetch the packed telemetry
        vector — still ONE blocking transfer per step; a jit compile is
        detected from step-cache growth and recorded as a compile span."""
        cache0 = int(self._step_fn._cache_size())
        with obs.span("launch"):
            t0 = time.perf_counter()
            out = self._dispatch(pg)
            if int(self._step_fn._cache_size()) > cache0:
                obs.record_span("compile", time.perf_counter() - t0)
        with obs.span("sync"):
            tel = obs.unpack_step_telemetry(
                np.asarray(jax.device_get(out[-1])))
        self._metrics.count("host_syncs")
        return out, tel


__all__ = [
    "STATIC_SCENE_OPTS",
    "ShardOpts",
    "ShardedIndex",
    "ShardedSession",
    "SlabLayout",
    "make_sharded_query",
    "plan_layout",
    "route_points",
    "route_queries",
    "shard_scene",
    "unroute_results",
]
