"""RTNN-on-TPU core library: the paper's contribution as composable JAX.

Public API:
    build_index, query, update_index      pure functional core (repro.api,
    NeighborIndex, QueryPlan              DESIGN.md section 8)
    NeighborSearch, neighbor_search       eager host-planned search
                                          (Listings 1-3; shim over the core)
    SearchParams, SearchOpts, SearchResult, GridSpec
    build_cell_grid, choose_grid_spec     acceleration structure
    schedule_queries                      section 4 query scheduling
    compute_megacells, plan_partitions    section 5.1 partitioning
    plan_bundles, CostModel               section 5.2 bundling
"""
from .types import (Array, CellGrid, GridSpec, SearchOpts, SearchParams,
                    SearchResult, UpdateStats)
from .grid import (build_cell_grid, choose_grid_spec, box_count,
                   update_cell_grid, update_cell_grid_traced)
from .morton import morton_encode, morton_decode, morton_argsort
from .schedule import (schedule_queries, schedule_by_level,
                       coherence_statistic)
from .partition import (MegacellStatics, Partition, PartitionPlan,
                        compute_megacells, launch_signatures,
                        megacell_statics, plan_partitions, signature_levels)
from .bundle import Bundle, CostModel, calibrate, exhaustive_best, plan_bundles
from .schedule import schedule_cells
from .search import (NeighborSearch, neighbor_search, window_search,
                     window_tile_search)
from .api import (NeighborIndex, QueryPlan, build_index, cached_searcher,
                  execute_plan, plan_query, query, query_concat,
                  update_index)
from .executor import PendingResult, PlanHandle, QueryExecutor
from .dynamic import (SessionOpts, SimulationSession, StepReport,
                      session_grid_spec)
from .shards import (ShardOpts, ShardedIndex, ShardedSession, SlabLayout,
                     plan_layout, shard_scene)

__all__ = [
    "NeighborIndex", "QueryPlan", "build_index", "cached_searcher",
    "execute_plan", "plan_query", "query", "query_concat", "update_index",
    "PendingResult", "PlanHandle", "QueryExecutor", "SessionOpts",
    "SimulationSession",
    "StepReport", "UpdateStats", "schedule_cells", "session_grid_spec",
    "update_cell_grid", "update_cell_grid_traced",
    "Array", "CellGrid", "GridSpec", "SearchOpts", "SearchParams",
    "SearchResult", "build_cell_grid", "choose_grid_spec", "box_count",
    "morton_encode", "morton_decode", "morton_argsort", "schedule_queries",
    "schedule_by_level", "coherence_statistic", "MegacellStatics",
    "Partition", "PartitionPlan", "compute_megacells", "launch_signatures",
    "megacell_statics", "plan_partitions", "signature_levels", "Bundle",
    "CostModel", "calibrate", "exhaustive_best", "plan_bundles",
    "NeighborSearch", "neighbor_search", "window_search",
    "window_tile_search",
    "ShardOpts", "ShardedIndex", "ShardedSession", "SlabLayout",
    "plan_layout", "shard_scene",
]
