"""Query partitioning via megacells (paper section 5.1).

Per query, grow a cube of grid cells ("megacell") around the query's cell
until it holds >= K points or its next growth would cross the r-sphere
boundary — exactly the paper's iterative 6-direction growth, evaluated in
O(1) per ring with the grid's summed-area table instead of a CUDA kernel.

The megacell determines the per-query *candidate window radius in cells*
(``w_search``), the TPU analogue of the paper's per-partition AABB width
(DESIGN.md section 2): it fixes the static shape of the candidate gather and
hence the distance work per query (Observation 2's cubic law).

Window sizing:
  range:          w_search = w*           (megacell itself; the paper's
                  "AABB = megacell" case, sphere test skippable because the
                  megacell is inscribed in the r-sphere)
  knn heuristic:  S = 2*(3/(4*pi))^(1/3) * a   (paper's equi-volume estimate)
  knn exact:      S = sqrt(3) * a              (paper's conservative
                  circumsphere bound, Fig. 10c)
where a = (2*w*+1)*cell is the megacell width; all windows are clamped to
the full-radius window w_full = ceil(r/cell).
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from .grid import box_count, clamp_box
from .types import Array, CellGrid, SearchParams

# paper section 5.1: 2 * cbrt(3 / (4 pi))
_HEURISTIC_FACTOR = 2.0 * (3.0 / (4.0 * math.pi)) ** (1.0 / 3.0)
_EXACT_FACTOR = math.sqrt(3.0)


def full_window_radius(cell_size: float, radius: float) -> int:
    """Window radius (cells) that always covers the r-ball of any query."""
    return max(1, int(math.ceil(radius / cell_size - 1e-6)))


def max_inscribed_ring(cell_size: float, radius: float) -> int:
    """Largest ring w such that the megacell [c-w, c+w] is guaranteed inside
    the r-sphere of any query in cell c: sqrt(3)*(w+1)*cell <= r."""
    return int(math.floor(radius / (math.sqrt(3.0) * cell_size) + 1e-6)) - 1


@dataclasses.dataclass(frozen=True)
class MegacellStatics:
    """Host-static derived quantities of a (grid, params) pair."""

    w_full: int
    w_sph: int        # max sphere-inscribed ring (-1: none)
    w_loop: int       # rings actually examined (min(w_sph, opts.w_max))

    @property
    def has_megacells(self) -> bool:
        return self.w_loop >= 0


def megacell_statics(cell_size: float, params: SearchParams,
                     w_max: int) -> MegacellStatics:
    w_sph = max_inscribed_ring(cell_size, params.radius)
    return MegacellStatics(
        w_full=full_window_radius(cell_size, params.radius),
        w_sph=w_sph,
        w_loop=min(w_max, w_sph),
    )


def _window_from_ring(w_star: Array, found: Array, st: MegacellStatics,
                      params: SearchParams) -> tuple[Array, Array]:
    """Map megacell ring -> (w_search, skip_test) per query."""
    a_cells = 2 * w_star + 1                     # megacell width in cells
    if params.mode == "range":
        w_search = jnp.where(found, w_star, st.w_full)
        skip = found
    else:
        factor = (_EXACT_FACTOR if params.knn_window == "exact"
                  else _HEURISTIC_FACTOR)
        # half-width of the paper's KNN AABB, in cells, covered from the
        # query's own cell: w*cell >= S/2  ->  w = ceil(factor*a/2)
        w_knn = jnp.ceil(0.5 * factor * a_cells - 1e-6).astype(jnp.int32)
        w_search = jnp.where(found, jnp.minimum(w_knn, st.w_full), st.w_full)
        skip = jnp.zeros_like(found)             # knn always distance-filters
    return w_search.astype(jnp.int32), skip


@partial(jax.jit, static_argnames=("statics", "params"))
def compute_megacells(
    grid: CellGrid,
    queries: Array,
    statics: MegacellStatics,
    params: SearchParams,
    origin: Array | None = None,
) -> tuple[Array, Array, Array]:
    """Vectorized megacell growth.

    Returns per-query ``(w_search, skip_test, rho)`` where ``rho`` is the
    paper's density estimate K/C^3 used by the bundling cost model
    (section 5.2), with C the megacell width. ``origin`` overrides the
    static spec origin for the cell lookup (sharded slabs, whose local
    frames differ per shard while the spec is shared).
    """
    nq = queries.shape[0]
    spec = grid.spec
    ccoord = spec.cell_of(queries, origin)

    if not statics.has_megacells:
        w_search = jnp.full((nq,), statics.w_full, jnp.int32)
        skip = jnp.zeros((nq,), bool)
        vol = (2.0 * params.radius) ** 3
        rho = jnp.full((nq,), params.k / vol, jnp.float32)
        return w_search, skip, rho

    # counts for every ring 0..w_loop — O(1) each via the SAT
    ring_counts = []
    for w in range(statics.w_loop + 1):
        lo, hi = clamp_box(spec, ccoord, w)
        ring_counts.append(box_count(grid.sat, lo, hi))
    counts = jnp.stack(ring_counts, axis=-1)            # [Nq, w_loop+1]

    satisfied = counts >= params.k                       # monotone in w
    found = jnp.any(satisfied, axis=-1)
    w_star = jnp.argmax(satisfied, axis=-1).astype(jnp.int32)

    w_search, skip = _window_from_ring(w_star, found, statics, params)

    a = (2.0 * w_star.astype(jnp.float32) + 1.0) * spec.cell_size
    rho_found = params.k / jnp.maximum(a**3, 1e-30)
    # unfound queries search the full r-window; estimate density from the
    # largest examined ring
    a_last = (2.0 * statics.w_loop + 1.0) * spec.cell_size
    rho_fallback = counts[..., -1].astype(jnp.float32) / (a_last**3)
    rho = jnp.where(found, rho_found, jnp.maximum(rho_fallback, 1e-12))
    return w_search, skip, rho.astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class Partition:
    """One query partition: all queries sharing a window radius/skip flag."""

    w_search: int
    skip_test: bool
    count: int            # number of queries (N_i in the cost model)
    rho: float            # mean density estimate (rho_i)
    start: int            # offset into the partition-sorted query order


@dataclasses.dataclass
class PartitionPlan:
    """Host-side partition layout: queries sorted by (partition key, Morton
    slot) and the per-partition metadata for bundling."""

    perm: np.ndarray              # partition-sorted order over *scheduled* idx
    partitions: list[Partition]
    w_full: int

    @property
    def num_partitions(self) -> int:
        return len(self.partitions)


def trivial_plan(nq: int, w_full: int) -> PartitionPlan:
    """Single full-window partition (partitioning disabled / no megacells)."""
    part = Partition(w_search=w_full, skip_test=False, count=nq, rho=1.0,
                     start=0)
    return PartitionPlan(perm=np.arange(nq), partitions=[part],
                         w_full=w_full)


def inflate_plan_inputs(
    w_search: np.ndarray,
    skip: np.ndarray,
    *,
    margin: int,
    w_full: int,
    w_sph: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Staleness contract for cross-frame plan reuse (DESIGN.md section 7).

    A partition plan captured at frame t stays *exact* at frame t+s as long
    as every point/query has drifted less than half a cell since capture,
    provided each per-query window is inflated by ``margin`` cells (one cell
    absorbs candidate drift, one absorbs the query's own cell shift — the
    session's displacement threshold is calibrated to this). Windows stay
    clamped to ``w_full`` (which always covers the full r-ball, so inflation
    never loses exactness), and the sphere-test skip is revoked for any
    window the inflation pushed past the inscribed ring ``w_sph``.
    """
    w = np.minimum(w_search.astype(np.int64) + int(margin),
                   int(w_full)).astype(w_search.dtype)
    s = skip.astype(bool) & (w <= w_sph)
    return w, s


def launch_signatures(
    statics: MegacellStatics,
    params: SearchParams,
    *,
    margin: int = 0,
    enabled: bool = True,
    w_ladder: tuple[int, ...] | None = None,
) -> tuple[tuple[int, bool], ...]:
    """Static launch-signature ladder of the traced query path.

    The host-planned executor groups bundles by their data-dependent
    ``(w_search, skip_test)`` signature; a traced query cannot (shapes must
    be static), so the functional core (``core/api.py``) instead enumerates
    every signature a query can possibly be assigned — the megacell rings
    ``0..w_loop`` mapped through the paper's window sizing, plus the
    full-radius fallback — entirely from host-static quantities, and
    dispatches each query tile to its ladder entry with ``lax.switch``.

    ``margin`` bakes the staleness inflation of ``inflate_plan_inputs``
    into the ladder (captured plans for the dynamic session). ``w_ladder``
    (``SearchOpts.w_ladder``) overrides the derived window set with an
    explicit one; queries then round UP to the nearest ladder window and
    the sphere-test skip is disabled (a coarser-but-always-exact ladder
    that bounds the ``lax.switch`` branch count).

    The fused traced path derives its static launch *sizes* from this
    ladder too: ``kernels/ops.segment_levels`` extends the ``2w+1``
    signature windows with geometric escalations (a Morton tile's shared
    window must also cover the tile's cell spread) capped at the grid
    dims — the host-static bound that keeps the scalar-prefetch Pallas
    schedule's shapes static (DESIGN.md section 3).
    """
    return _launch_signatures_cached(statics, params, margin, enabled,
                                     w_ladder)


@lru_cache(maxsize=256)
def _launch_signatures_cached(statics, params, margin, enabled, w_ladder):
    # partitioning inactive -> every query needs the full-radius window;
    # a coarser explicit ladder has no per-query levels to dispatch on and
    # must not shadow this (plan_query assigns level 0 to everything)
    if not enabled or not statics.has_megacells:
        return ((statics.w_full, False),)
    if w_ladder is not None:
        ws = sorted({int(w) for w in w_ladder if 0 <= int(w)}
                    | {statics.w_full})
        return tuple((w, False) for w in ws if w <= statics.w_full)
    pairs = {(statics.w_full, False)}        # not-found / fallback signature
    # evaluate the traced ring->window map eagerly on every concrete ring so
    # the ladder windows are bit-identical to compute_megacells' values
    # (compile-time eval: launch_signatures is also reached from inside
    # jitted programs, where plain jnp ops would return tracers)
    with jax.ensure_compile_time_eval():
        rings = jnp.arange(statics.w_loop + 1, dtype=jnp.int32)
        w_r, s_r = _window_from_ring(rings, jnp.ones_like(rings, bool),
                                     statics, params)
        w_list = np.asarray(w_r).tolist()
        s_list = np.asarray(s_r).tolist()
    for w, s in zip(w_list, s_list):
        w2 = min(int(w) + margin, statics.w_full)
        pairs.add((w2, bool(s) and w2 <= statics.w_sph))
    return tuple(sorted(pairs))


def signature_levels(
    w_search: Array,
    skip: Array,
    ladder: tuple[tuple[int, bool], ...],
) -> Array:
    """Per-query index into ``ladder`` (traced).

    With a derived ladder every ``(w_search, skip)`` pair matches one entry
    exactly by construction; with an explicit ``SearchOpts.w_ladder`` the
    query rounds up to the smallest ladder window >= ``w_search`` (skips
    are revoked by construction there, so matching on ``w`` suffices).
    """
    exact = jnp.zeros(w_search.shape, jnp.int32)
    matched = jnp.zeros(w_search.shape, bool)
    for i, (wl, sl) in enumerate(ladder):
        hit = (w_search == wl) & (skip == sl)
        exact = jnp.where(hit, jnp.int32(i), exact)
        matched = matched | hit
    if any(s for _, s in ladder):
        # derived ladder: every pair matches by construction; the defensive
        # fallback must never land on a skip entry (eliding the r^2 filter
        # is only sound for the exact megacell signature)
        fb = max(i for i, (_, s) in enumerate(ladder) if not s)
        fallback = jnp.full(w_search.shape, fb, jnp.int32)
    else:
        ws = jnp.asarray([w for w, _ in ladder], jnp.int32)
        fallback = jnp.clip(
            jnp.searchsorted(ws, w_search.astype(jnp.int32), side="left"),
            0, len(ladder) - 1).astype(jnp.int32)
    return jnp.where(matched, exact, fallback)


def plan_partitions(
    w_search: Array,
    skip: Array,
    rho: Array,
    w_full: int,
) -> PartitionPlan:
    """Group queries into partitions (host orchestration, like the paper's
    host-side partition launch loop in Listing 3). Accepts device arrays or
    host numpy (the executor passes the already-fetched plan metadata)."""
    w_np = np.asarray(jax.device_get(w_search))
    s_np = np.asarray(jax.device_get(skip))
    r_np = np.asarray(jax.device_get(rho))
    key = w_np.astype(np.int64) * 2 + s_np.astype(np.int64)
    # stable sort keeps the Morton schedule order within each partition
    perm = np.argsort(key, kind="stable")
    key_sorted = key[perm]
    uniq, starts, counts = np.unique(key_sorted, return_index=True,
                                     return_counts=True)
    parts = []
    for u, st, cn in zip(uniq, starts, counts):
        sel = perm[st:st + cn]
        parts.append(Partition(
            w_search=int(u // 2),
            skip_test=bool(u % 2),
            count=int(cn),
            rho=float(r_np[sel].mean()),
            start=int(st),
        ))
    return PartitionPlan(perm=perm, partitions=parts, w_full=int(w_full))
