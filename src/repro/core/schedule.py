"""Spatially-ordered query scheduling (paper section 4).

The paper finds one enclosing leaf AABB per query with a truncated (K=1) ray
pass, then Morton-sorts queries by that AABB's center. On the uniform grid
the enclosing "AABB" of a query is its containing cell, available in closed
form, so the scheduling pass is pure index arithmetic — the truncated ray
trace's job (cheaply associating *some* spatial bucket with each query) is
preserved, its mechanism is not needed (DESIGN.md section 2).

Adjacent entries of the scheduled query array then live in the same or
Morton-adjacent cells, so consecutive query tiles gather the same candidate
cells: the TPU analogue of warp-coherent rays (paper Observation 1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .morton import morton_encode
from .types import Array, GridSpec


@partial(jax.jit, static_argnames=("spec",))
def schedule_queries(spec: GridSpec, queries: Array) -> tuple[Array, Array]:
    """Return (perm, inv_perm) ordering ``queries`` [Nq, 3] spatially.

    ``perm`` maps scheduled slot -> original query index; ``inv_perm`` maps
    original index -> scheduled slot (used to scatter results back).
    """
    return schedule_cells(spec.cell_of(queries))


@jax.jit
def schedule_cells(ccoord: Array) -> tuple[Array, Array]:
    """Schedule from precomputed integer cell coordinates [Nq, 3].

    The dynamic-scene self-query fast path (``core/dynamic.py``) shares ONE
    cell assignment between the grid update and the query schedule — the
    incremental update already binned the points, so replanning a session
    never recomputes ``cell_of``.
    """
    code = morton_encode(ccoord)
    perm = jnp.argsort(code)
    n = ccoord.shape[0]
    inv = jnp.zeros((n,), jnp.int32).at[perm].set(
        jnp.arange(n, dtype=jnp.int32))
    return perm, inv


def coherence_statistic(spec: GridSpec, queries: Array) -> Array:
    """Fraction of adjacent query pairs sharing a grid cell — the proxy we
    report for the paper's Fig. 6 cache/occupancy microarchitecture numbers
    (not measurable on this backend)."""
    flat = spec.flat_cell(spec.cell_of(queries))
    return jnp.mean((flat[1:] == flat[:-1]).astype(jnp.float32))
