"""Spatially-ordered query scheduling (paper section 4).

The paper finds one enclosing leaf AABB per query with a truncated (K=1) ray
pass, then Morton-sorts queries by that AABB's center. On the uniform grid
the enclosing "AABB" of a query is its containing cell, available in closed
form, so the scheduling pass is pure index arithmetic — the truncated ray
trace's job (cheaply associating *some* spatial bucket with each query) is
preserved, its mechanism is not needed (DESIGN.md section 2).

Adjacent entries of the scheduled query array then live in the same or
Morton-adjacent cells, so consecutive query tiles gather the same candidate
cells: the TPU analogue of warp-coherent rays (paper Observation 1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .morton import morton_encode
from .types import Array, GridSpec


@partial(jax.jit, static_argnames=("spec",))
def schedule_queries(spec: GridSpec, queries: Array) -> tuple[Array, Array]:
    """Return (perm, inv_perm) ordering ``queries`` [Nq, 3] spatially.

    ``perm`` maps scheduled slot -> original query index; ``inv_perm`` maps
    original index -> scheduled slot (used to scatter results back).
    """
    return schedule_cells(spec.cell_of(queries))


@jax.jit
def schedule_cells(ccoord: Array) -> tuple[Array, Array]:
    """Schedule from precomputed integer cell coordinates [Nq, 3].

    The dynamic-scene self-query fast path (``core/dynamic.py``) shares ONE
    cell assignment between the grid update and the query schedule — the
    incremental update already binned the points, so replanning a session
    never recomputes ``cell_of``.
    """
    code = morton_encode(ccoord)
    perm = jnp.argsort(code)
    n = ccoord.shape[0]
    inv = jnp.zeros((n,), jnp.int32).at[perm].set(
        jnp.arange(n, dtype=jnp.int32))
    return perm, inv


def schedule_by_level(ccoord: Array, levels: Array,
                      morton: bool = True) -> Array:
    """Traced ``(level, Morton)`` lexicographic ordering.

    The functional core's counterpart of ``plan_partitions``' stable host
    sort: queries are Morton-scheduled first, then stably sorted by their
    launch-signature level, so every launch group is a contiguous run of
    scheduled slots AND keeps the Morton coherence order within itself —
    identical layout discipline to the executor's signature-batched groups,
    derived entirely on device. This contiguity is also what the
    level-segmented Pallas schedule leans on (``kernels/ops``): each
    ladder level's tiles form one dense run, so the per-level masked
    launches skip long prefixes/suffixes of off-level tiles instead of
    interleaving them. ``morton=False`` mirrors
    ``SearchOpts(schedule=False)`` (input order within each level).
    """
    n = ccoord.shape[0]
    if morton:
        perm0 = jnp.argsort(morton_encode(ccoord)).astype(jnp.int32)
    else:
        perm0 = jnp.arange(n, dtype=jnp.int32)
    return perm0[jnp.argsort(levels[perm0], stable=True)]


def coherence_statistic(spec: GridSpec, queries: Array) -> Array:
    """Fraction of adjacent query pairs sharing a grid cell — the proxy we
    report for the paper's Fig. 6 cache/occupancy microarchitecture numbers
    (not measurable on this backend)."""
    flat = spec.flat_cell(spec.cell_of(queries))
    return jnp.mean((flat[1:] == flat[:-1]).astype(jnp.float32))
