"""Top-level neighbor search — paper Listings 1-3 as a JAX pipeline.

Pipeline (host orchestration mirrors the paper's host code):
  1. build the cell grid over the points              (Listing 1, buildBVH)
  2. schedule: Morton-order the queries               (section 4, Listing 2)
  3. partition: megacells -> per-query window         (section 5.1, Listing 3)
  4. bundle: cost-model launch plan                   (section 5.2)
  5. per bundle: tiled window search (jnp path or Pallas kernel path),
     scatter back through the inverse permutations.

Static-shape discipline: each bundle launch is jitted under a static
(window, skip, K, padded-N) signature; bundle query counts are padded to
power-of-two buckets so recompilation is bounded (DESIGN.md "padded-bucket
partitions").
"""
from __future__ import annotations

import dataclasses
import math
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# "topk" = partial selection (lax.top_k) on the candidate axis; "sort" =
# stable full argsort (oracle-identical tie order). Perf iteration 5.
_SELECTION = os.environ.get("REPRO_SELECTION", "topk")

from . import bundle as bundle_mod
from .partition import (MegacellStatics, PartitionPlan, compute_megacells,
                        megacell_statics, plan_partitions, trivial_plan)
from .schedule import schedule_queries
from .types import (Array, CellGrid, GridSpec, SearchOpts, SearchParams,
                    SearchResult)
from ..kernels.ref import pairwise_d2, topk_select


# ---------------------------------------------------------------------------
# per-bundle window search (jnp path; the Pallas path lives in kernels/ops)
# ---------------------------------------------------------------------------

def window_tile_search(
    grid: CellGrid,
    points: Array,
    qt: Array,
    spec: GridSpec,
    w: int,
    radius: float,
    k: int,
    skip_test: bool,
    origin: Array | None = None,
) -> tuple[Array, Array, Array]:
    """One query tile ``qt`` [T, 3] against the (2w+1)^3 window around each
    query's cell: ([T, k] d2, [T, k] idx, [T] cnt).

    The per-tile unit shared by the jitted ``window_search`` path and the
    traced launch-ladder branches of the functional core (``core/api.py``):
    both paths run the identical ops, so their results are bit-identical
    for the same ``w``/``skip_test`` signature.

    Step 1 (paper: ray-AABB on RT cores) is the regular window gather —
    pure index arithmetic. Step 2 (paper: IS shader sphere test) is the
    tiled pairwise-distance + bounded-K selection; with ``skip_test`` the
    r^2 filter is elided (paper's megacell-inscribed range-search case).
    """
    # per-axis window, clamped to the grid (thin-slab datasets like KITTI
    # have near-degenerate axes whose whole extent fits inside the window)
    ws = tuple(min(2 * w + 1, d) for d in spec.dims)
    cap = spec.capacity
    r2 = jnp.float32(radius) ** 2
    dims = jnp.asarray(spec.dims, jnp.int32)
    ws_arr = jnp.asarray(ws, jnp.int32)

    ccoord = spec.cell_of(qt, origin)                    # [T, 3]
    start = jnp.clip(ccoord - w, 0, dims - ws_arr)       # [T, 3]

    def gather_one(st):
        blk = jax.lax.dynamic_slice(
            grid.dense, (st[0], st[1], st[2], 0),
            (*ws, cap))
        return blk.reshape(-1)

    cand = jax.vmap(gather_one)(start)                   # [T, W^3*C]
    cand_pos = points[jnp.clip(cand, 0, points.shape[0] - 1)]
    d2 = _tile_d2(qt, cand_pos)                          # [T, W^3*C]
    invalid = cand < 0
    if not skip_test:
        invalid = invalid | (d2 > r2)
    d2 = jnp.where(invalid, jnp.inf, d2)
    idx = jnp.where(invalid, -1, cand)
    if _SELECTION == "topk":
        # partial selection O(M*K) instead of full argsort O(M log M)
        # over the candidate axis (Perf iteration 5, EXPERIMENTS.md)
        m = d2.shape[-1]
        kk = min(k, m)
        negd, sel = jax.lax.top_k(-d2, kk)
        d2k = jnp.pad(-negd, ((0, 0), (0, k - kk)),
                      constant_values=jnp.inf)
        idxk = jnp.pad(jnp.take_along_axis(idx, sel, axis=-1),
                       ((0, 0), (0, k - kk)), constant_values=-1)
        idxk = jnp.where(jnp.isinf(d2k), -1, idxk)
    else:
        d2k, idxk = topk_select(d2, idx, k)
    cnt = jnp.sum((idxk >= 0).astype(jnp.int32), axis=-1)
    return d2k, idxk, cnt


@partial(jax.jit,
         static_argnames=("spec", "w", "k", "skip_test", "tile"))
def window_search(
    grid: CellGrid,
    points: Array,
    queries: Array,
    spec: GridSpec,
    w: int,
    radius: float,
    k: int,
    skip_test: bool,
    tile: int = 256,
    origin: Array | None = None,
) -> tuple[Array, Array, Array]:
    """Search each query against the (2w+1)^3 cell window around its cell.

    Tiled driver over :func:`window_tile_search`. Padded rows are
    edge-replicates of the last real query (matching the host loop and the
    Pallas path) so they search that query's own window instead of all
    collapsing into the origin cell's window — zero-padding wasted gathers
    and distorted the Pallas tile-window anchors.
    """
    nq = queries.shape[0]
    npad = (-nq) % tile
    if npad:
        queries = jnp.pad(queries, ((0, npad), (0, 0)), mode="edge")

    def one_tile(qt):
        return window_tile_search(grid, points, qt, spec, w, radius, k,
                                  skip_test, origin)

    d2c, idxc, cntc = jax.lax.map(one_tile, queries.reshape(-1, tile, 3))
    return (idxc.reshape(-1, k)[:nq], d2c.reshape(-1, k)[:nq],
            cntc.reshape(-1)[:nq])


def _tile_d2(q: Array, cand_pos: Array) -> Array:
    """[T, 3] x [T, M, 3] -> [T, M] squared distances (batched MXU form)."""
    qn = jnp.sum(q * q, axis=-1, keepdims=True)              # [T, 1]
    pn = jnp.sum(cand_pos * cand_pos, axis=-1)               # [T, M]
    cross = jnp.einsum("td,tmd->tm", q, cand_pos)
    return jnp.maximum(qn + pn - 2.0 * cross, 0.0)


def _pad_bucket(n: int, tile: int) -> int:
    """Next power-of-two multiple of ``tile`` >= n (recompile bounding)."""
    base = max(tile, int(2 ** math.ceil(math.log2(max(n, 1)))))
    return int(math.ceil(base / tile) * tile)


# ---------------------------------------------------------------------------
# orchestrator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SearchReport:
    """Execution breakdown mirroring paper Fig. 12 categories, plus the
    executor's dispatch/sync counters (DESIGN.md section 3)."""

    t_build: float = 0.0       # BVH   (grid build)
    t_opt: float = 0.0         # Opt   (schedule + partition + bundle planning)
    t_fs: float = 0.0          # FS    (first-hit pass; closed-form here)
    t_search: float = 0.0      # Search
    bundles: list = dataclasses.field(default_factory=list)
    num_partitions: int = 0
    launches: int = 0          # device dispatches in the last query
    host_syncs: int = 0        # blocking result materializations (executor: 1)
    plan_fetches: int = 0      # small plan-metadata transfers (executor: <=1)


class NeighborSearch:
    """RTNN-style neighbor search over a fixed point set.

    >>> ns = NeighborSearch(points, SearchParams(radius=0.1, k=8))
    >>> res = ns.query(queries)          # SearchResult in query order
    """

    def __init__(
        self,
        points,
        params: SearchParams,
        opts: SearchOpts = SearchOpts(),
        spec: GridSpec | None = None,
        cost_model: bundle_mod.CostModel | None = None,
    ):
        from .api import build_index
        self.params = params
        self.opts = opts
        self.cost_model = cost_model or bundle_mod.CostModel()
        # thin shim over the functional core: the structure is a
        # NeighborIndex (core/api.py); the executor below is the
        # host-planned optimizing path over the same leaves
        self.index = build_index(points, params, opts, spec=spec)
        self.spec = self.index.spec
        self.points = self.index.points
        self.grid = self.index.grid
        self.statics = self.index.statics
        self.report = SearchReport()
        from .executor import QueryExecutor
        self.executor = QueryExecutor(self)

    # -- pipeline stages ----------------------------------------------------

    def _schedule(self, queries: Array) -> tuple[Array, Array]:
        if not self.opts.schedule:
            n = queries.shape[0]
            eye = jnp.arange(n, dtype=jnp.int32)
            return eye, eye
        return schedule_queries(self.spec, queries)

    def _partition(self, queries_s: Array) -> PartitionPlan:
        nq = queries_s.shape[0]
        if not self.opts.partition or not self.statics.has_megacells:
            return trivial_plan(nq, self.statics.w_full)
        w_search, skip, rho = compute_megacells(
            self.grid, queries_s, self.statics, self.params)
        return plan_partitions(w_search, skip, rho, self.statics.w_full)

    def _bundle(self, plan: PartitionPlan) -> list[bundle_mod.Bundle]:
        return bundle_mod.plan_bundles(
            plan.partitions, self.cost_model,
            n_points=int(self.points.shape[0]),
            cell_size=self.spec.cell_size,
            mode=self.params.mode, k=self.params.k,
            w_sph=self.statics.w_sph,
            enable=self.opts.bundle,
        )

    # -- execution ----------------------------------------------------------

    def query(self, queries) -> SearchResult:
        """Search ``queries`` [Nq, 3]; results come back in query order.

        Default path is the device-resident ``QueryExecutor`` (async
        signature-batched launches, on-device scatter, one host sync —
        DESIGN.md section 3); ``SearchOpts(executor=False)`` keeps the
        legacy per-bundle host loop for A/B benchmarking.
        """
        if self.opts.executor:
            return self.executor.execute(queries)
        return self._query_host_loop(queries)

    def _query_host_loop(self, queries) -> SearchResult:
        import time
        queries = jnp.asarray(queries, jnp.float32)
        nq = queries.shape[0]
        k = self.params.k

        t0 = time.perf_counter()
        perm, inv = self._schedule(queries)
        queries_s = jnp.asarray(queries)[perm]
        plan = self._partition(queries_s)
        bundles = self._bundle(plan)
        self.report.t_opt = time.perf_counter() - t0
        self.report.num_partitions = plan.num_partitions
        self.report.bundles = bundles

        out_idx = np.full((nq, k), -1, np.int32)
        out_d2 = np.full((nq, k), np.inf, np.float32)
        out_cnt = np.zeros((nq,), np.int32)
        perm_np = np.asarray(jax.device_get(perm))

        t0 = time.perf_counter()
        for b in bundles:
            sel_sched = bundle_mod.bundle_query_sel(plan, b)
            qb = queries_s[jnp.asarray(sel_sched)]
            pad_n = _pad_bucket(qb.shape[0], self.opts.query_tile)
            # edge-replicate padding: padded rows are copies of a real query
            # so tile window anchors (pallas path) are not distorted
            qb = jnp.pad(qb, ((0, pad_n - qb.shape[0]), (0, 0)), mode="edge")
            searcher = self._searcher()
            idx, d2, cnt = searcher(
                self.grid, self.points, qb, self.spec,
                int(b.w_search), self.params.radius, k,
                bool(b.skip_test), self.opts.query_tile)
            n_b = sel_sched.shape[0]
            orig = perm_np[sel_sched]
            out_idx[orig] = np.asarray(jax.device_get(idx))[:n_b]
            out_d2[orig] = np.asarray(jax.device_get(d2))[:n_b]
            out_cnt[orig] = np.asarray(jax.device_get(cnt))[:n_b]
        self.report.t_search = time.perf_counter() - t0
        self.report.launches = len(bundles)
        # per bundle: 3 blocking result transfers; +1 for the perm fetch
        self.report.host_syncs = 3 * len(bundles) + 1
        self.report.plan_fetches = 3 if (self.opts.partition and
                                         self.statics.has_megacells) else 0

        return SearchResult(indices=jnp.asarray(out_idx),
                            distances2=jnp.asarray(out_d2),
                            counts=jnp.asarray(out_cnt))

    def _searcher(self):
        # both searchers are pure traced JAX with the same positional
        # signature; the Pallas one runs the level-segmented fused schedule
        # (device tile anchors by scalar prefetch, kernels/ops), so the
        # executor compiles either into its one-program launch schedule
        if self.opts.use_pallas:
            from ..kernels.ops import window_search_pallas
            return window_search_pallas
        return window_search


def neighbor_search(points, queries, radius: float, k: int,
                    mode: str = "knn",
                    opts: SearchOpts = SearchOpts(),
                    knn_window: str = "exact") -> SearchResult:
    """One-shot search (builds the structure and searches).

    Routed through the keyed index cache of the functional core
    (``api.cached_searcher``): repeated one-shot calls over the same point
    set reuse the built grid and every plan/compile cache instead of
    discarding them per call.
    """
    from .api import cached_searcher
    params = SearchParams(radius=radius, k=k, mode=mode,
                          knn_window=knn_window)
    return cached_searcher(points, params, opts).query(queries)
