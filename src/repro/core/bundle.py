"""Partition bundling (paper section 5.2 + appendices A-C).

Cost model (first-order, per bundle):
    T = T_build + T_search
    T_build            = k_build * M              (M = structure size; the
                         paper's BVH build, our per-bundle grid re-fit, both
                         empirically linear — Fig. 15 / fig15 benchmark)
    T_search (KNN)     = k_knn   * sum_i N_i * rho_i * S^3     (eq. 4)
    T_search (range)   = k_range * sum_i N_i * K               (appendix A)
where S is the *bundle* window width max_i S_i, N_i/rho_i the member
partitions' query counts and density estimates. ``k_range`` is cheaper when
the sphere test is skippable (paper: 20:1 vs 2:1 against k_build per unit).

Bundling theorem (appendix C): under the empirical inverse correlation
between AABB size and query count, the optimal strategy with M0 bundles
keeps the (M0-1) largest-query-count partitions separate and merges the
rest; M0 is found by a linear scan. Implemented verbatim;
``exhaustive_best`` brute-forces all set-partitions for the property test.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Sequence

import numpy as np

from .partition import Partition


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Relative cost constants. Only ratios matter (paper section 5.2); the
    defaults reproduce the paper's RTX 2080 ratios (k_build:k_knn = 1:15000,
    k_build:k_range = 20:1 skippable / 2:1 tested) rescaled to k_build=1."""

    k_build: float = 1.0
    k_knn: float = 15000.0
    k_range_skip: float = 1.0 / 20.0
    k_range_test: float = 1.0 / 2.0

    def search_cost(self, parts: Sequence[Partition], w_bundle: int,
                    cell_size: float, mode: str, k: int,
                    skip_test: bool) -> float:
        if mode == "knn":
            s3 = ((2 * w_bundle + 1) * cell_size) ** 3
            return self.k_knn * sum(p.count * p.rho for p in parts) * s3
        kq = self.k_range_skip if skip_test else self.k_range_test
        return kq * sum(p.count for p in parts) * k


@dataclasses.dataclass(frozen=True)
class Bundle:
    """A set of partitions searched together with one structure/launch."""

    members: tuple[int, ...]      # indices into the PartitionPlan list
    w_search: int                 # max member window
    skip_test: bool
    count: int

    @property
    def signature(self) -> tuple[int, bool]:
        """Static launch signature ``(w_search, skip_test)`` — the key the
        executor folds same-shaped launches by, and the value domain of the
        functional core's static ladder (``partition.launch_signatures``)."""
        return (int(self.w_search), bool(self.skip_test))


def _mk_bundle(parts: Sequence[Partition], idxs: Sequence[int],
               w_sph: int) -> Bundle:
    ms = [parts[i] for i in idxs]
    w = max(p.w_search for p in ms)
    # a merged bundle may only skip the sphere test if every member could
    # and the merged window is still sphere-inscribed (DESIGN.md section 2)
    skip = all(p.skip_test for p in ms) and w <= w_sph
    return Bundle(members=tuple(idxs), w_search=w, skip_test=skip,
                  count=sum(p.count for p in ms))


def bundle_query_sel(plan, bundle: Bundle) -> np.ndarray:
    """Scheduled-order query positions of a bundle's member partitions,
    concatenated (shared by the executor's launch grouping and the legacy
    host loop so both paths stay bit-identical)."""
    return np.concatenate([
        plan.perm[p.start:p.start + p.count]
        for p in (plan.partitions[i] for i in bundle.members)
    ])


def bundle_cost(bundle: Bundle, parts: Sequence[Partition], model: CostModel,
                *, n_points: int, cell_size: float, mode: str,
                k: int) -> float:
    ms = [parts[i] for i in bundle.members]
    return model.k_build * n_points + model.search_cost(
        ms, bundle.w_search, cell_size, mode, k, bundle.skip_test)


def total_cost(bundles: Sequence[Bundle], parts: Sequence[Partition],
               model: CostModel, **kw) -> float:
    return sum(bundle_cost(b, parts, model, **kw) for b in bundles)


def plan_bundles(
    parts: Sequence[Partition],
    model: CostModel,
    *,
    n_points: int,
    cell_size: float,
    mode: str,
    k: int,
    w_sph: int,
    enable: bool = True,
) -> list[Bundle]:
    """Paper appendix C: sort by query count ascending; for each candidate
    bundle count M0, merge the (M - M0 + 1) smallest-N partitions, keep the
    rest separate; return the argmin-cost strategy. ``enable=False`` is the
    paper's Listing-3 default (one bundle per partition)."""
    m = len(parts)
    if m == 0:
        return []
    order = sorted(range(m), key=lambda i: parts[i].count)   # N ascending
    if not enable or m == 1:
        return [_mk_bundle(parts, (i,), w_sph) for i in range(m)]

    kw = dict(n_points=n_points, cell_size=cell_size, mode=mode, k=k)
    best: list[Bundle] | None = None
    best_cost = np.inf
    for m0 in range(1, m + 1):
        merged = order[: m - m0 + 1]
        separate = order[m - m0 + 1:]
        strat = [_mk_bundle(parts, tuple(sorted(merged)), w_sph)]
        strat += [_mk_bundle(parts, (i,), w_sph) for i in separate]
        c = total_cost(strat, parts, model, **kw)
        if c < best_cost:
            best_cost, best = c, strat
    assert best is not None
    return best


def exhaustive_best(
    parts: Sequence[Partition],
    model: CostModel,
    *,
    n_points: int,
    cell_size: float,
    mode: str,
    k: int,
    w_sph: int,
) -> tuple[list[Bundle], float]:
    """Brute-force optimal bundling over all set partitions (test oracle;
    the paper's "Oracle" variant in Fig. 13). Exponential — small M only."""
    m = len(parts)
    kw = dict(n_points=n_points, cell_size=cell_size, mode=mode, k=k)
    best, best_cost = None, np.inf
    for grouping in _set_partitions(list(range(m))):
        strat = [_mk_bundle(parts, tuple(g), w_sph) for g in grouping]
        c = total_cost(strat, parts, model, **kw)
        if c < best_cost:
            best_cost, best = c, strat
    return best, float(best_cost)


def _set_partitions(items: list[int]):
    if len(items) == 1:
        yield [items]
        return
    first, rest = items[0], items[1:]
    for smaller in _set_partitions(rest):
        for i in range(len(smaller)):
            yield smaller[:i] + [[first] + smaller[i]] + smaller[i + 1:]
        yield [[first]] + smaller


def calibrate(build_fn, n_build_units: int, search_fn, n_search_units: float,
              *, repeats: int = 3) -> CostModel:
    """Offline profiling of the k_build : k_search ratios on this backend
    (paper: "obtained offline through profiling"). ``build_fn()`` builds a
    structure over ``n_build_units`` points; ``search_fn()`` performs
    ``n_search_units`` units of search work (N*rho*S^3 for KNN). Both must
    block until ready (call ``.block_until_ready()``)."""

    def _time(f):
        f()  # warmup/compile
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            f()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    k_build = _time(build_fn) / max(n_build_units, 1)
    k_search = _time(search_fn) / max(n_search_units, 1e-9)
    scale = 1.0 / k_build
    return CostModel(k_build=1.0, k_knn=k_search * scale,
                     k_range_skip=k_search * scale / 20.0,
                     k_range_test=k_search * scale / 2.0)
