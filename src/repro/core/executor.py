"""Device-resident bundle executor (DESIGN.md section 3).

The legacy orchestrator (``NeighborSearch._query_host_loop``) ran a Python
loop over bundles with a blocking ``jax.device_get`` + numpy scatter per
bundle — giving back on the host most of what scheduling/partitioning won
on the device, exactly the naive-mapping overhead the paper warns about.
The executor keeps the whole execution phase device-resident:

  * **signature batching** — bundles sharing a static launch signature
    ``(w_search, skip_test, padded-N bucket)`` are folded into one padded
    launch with concatenated segment metadata, so B bundles become
    ~|unique signatures| dispatches instead of B;
  * **async dispatch + on-device scatter** — the whole launch schedule
    (per group: gather -> padded search -> scatter through the composed
    schedule∘partition permutation with ``.at[].set``) runs as ONE jitted
    program on the jnp path, and as a loop of non-blocking dispatches on
    the Pallas path. No per-bundle ``device_get``, no numpy scatter;
  * **one-sync contract** — exactly ONE blocking host sync materializes
    the results (``jax.block_until_ready`` over the three output arrays).
    The only other host transfer is the *plan fetch*: one fused
    ``device_get`` of the per-query partition metadata (w_search / skip /
    rho, plus query cells on the Pallas path) that data-dependent
    partitioning requires, mirroring the paper's host-side launch
    orchestration. Both are counted in ``stats()``;
  * **plan + compile caching** — host partition/bundle plans are cached
    by value fingerprint and compiled searchers are cached per launch
    signature (the jit cache does the compiling; the executor tracks
    first-seen signatures and jit cache sizes so ``stats()`` can prove a
    steady-state query recompiles nothing).
"""
from __future__ import annotations

import collections
import hashlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from .bundle import bundle_query_sel
from .partition import (PartitionPlan, compute_megacells, plan_partitions,
                        trivial_plan)
from .types import Array, SearchResult

_PLAN_CACHE_MAX = 32
_LAUNCHER_CACHE_MAX = 32


def _fingerprint(*arrays: np.ndarray) -> bytes:
    h = hashlib.sha1()
    for a in arrays:
        h.update(np.ascontiguousarray(a).tobytes())
    return h.digest()


class LaunchGroup:
    """One padded device launch covering every bundle of one signature."""

    __slots__ = ("w_search", "skip_test", "sel", "pad_n", "n_bundles")

    def __init__(self, w_search: int, skip_test: bool, sel: np.ndarray,
                 pad_n: int, n_bundles: int):
        self.w_search = w_search
        self.skip_test = skip_test
        self.sel = sel              # scheduled-order query positions
        self.pad_n = pad_n
        self.n_bundles = n_bundles


class QueryExecutor:
    """Executes a ``NeighborSearch``'s bundle plan device-resident.

    Owned by the search object (``ns.executor``); reusable across queries —
    steady-state repeated queries hit the plan cache and compile nothing.
    Surface: ``execute()`` (called by ``NeighborSearch.query``),
    ``warmup()``, ``stats()``.
    """

    def __init__(self, ns):
        self.ns = ns
        self._plan_cache: collections.OrderedDict = collections.OrderedDict()
        self._launcher_cache: collections.OrderedDict = \
            collections.OrderedDict()
        self._signatures: set = set()
        self._totals = collections.Counter()
        self._last: dict = {}

    # -- planning -----------------------------------------------------------

    def _plan(self, queries_s: Array):
        """Fetch partition metadata (ONE fused device_get), then plan and
        group on host — or reuse a cached plan for this fingerprint."""
        ns = self.ns
        nq = queries_s.shape[0]
        need_cells = ns.opts.use_pallas
        partitioned = ns.opts.partition and ns.statics.has_megacells

        fetch = []
        if partitioned:
            w_dev, s_dev, r_dev = compute_megacells(
                ns.grid, queries_s, ns.statics, ns.params)
            fetch += [w_dev, s_dev, r_dev]
        if need_cells:
            fetch.append(ns.spec.cell_of(queries_s))
        if fetch:
            fetched = [np.asarray(a) for a in jax.device_get(tuple(fetch))]
            self._last["plan_fetches"] += 1
        qcells = fetched.pop() if need_cells else None

        if partitioned:
            w_np, s_np, r_np = fetched[:3]
            key = (nq, _fingerprint(w_np, s_np, r_np))
        else:
            key = (nq, b"nopart")

        hit = self._plan_cache.get(key)
        if hit is not None:
            self._plan_cache.move_to_end(key)
            self._last["plan_cache_hit"] = True
            plan, bundles, groups = hit
            return plan, bundles, groups, qcells

        plan = (plan_partitions(w_np, s_np, r_np, ns.statics.w_full)
                if partitioned else trivial_plan(nq, ns.statics.w_full))
        bundles = ns._bundle(plan)
        groups = self._build_groups(plan, bundles)
        self._plan_cache[key] = (plan, bundles, groups)
        if len(self._plan_cache) > _PLAN_CACHE_MAX:
            self._plan_cache.popitem(last=False)
        return plan, bundles, groups, qcells

    def _build_groups(self, plan: PartitionPlan,
                      bundles) -> list[LaunchGroup]:
        """Fold bundles sharing (w_search, skip_test) into one launch."""
        from .search import _pad_bucket

        by_sig: dict = {}
        order: list = []
        for b in bundles:
            sig = (int(b.w_search), bool(b.skip_test))
            if sig not in by_sig:
                by_sig[sig] = []
                order.append(sig)
            by_sig[sig].append(bundle_query_sel(plan, b))
        groups = []
        for sig in order:
            sels = by_sig[sig]
            sel = (sels[0] if len(sels) == 1
                   else np.concatenate(sels)).astype(np.int64)
            groups.append(LaunchGroup(
                w_search=sig[0], skip_test=sig[1], sel=sel,
                pad_n=_pad_bucket(sel.shape[0], self.ns.opts.query_tile),
                n_bundles=len(sels)))
        return groups

    # -- compiled launch schedules ------------------------------------------

    def _get_launcher(self, groups, nq: int):
        """One jitted program running the WHOLE launch schedule: per group
        gather -> padded window search -> on-device scatter through the
        composed schedule∘partition permutation. Cached by the plan's
        *padded-bucket* shape ``(w, skip, pad_n)`` per group, NOT by exact
        query counts or plan values: the selection vector is edge-padded to
        the bucket on the host, so steady-state queries whose partition
        counts drift within the same buckets (SPH stepping) reuse the
        compiled schedule unchanged.

        The Pallas path is excluded (its tile-window anchors are host
        metadata computed from the plan fetch) and uses the per-group
        dispatch loop in ``execute`` instead.
        """
        ns = self.ns
        if ns.opts.use_pallas:
            return None
        metas = tuple((g.w_search, g.skip_test, g.pad_n) for g in groups)
        key = (metas, nq, ns.params.k, ns.opts.query_tile)
        launcher = self._launcher_cache.get(key)
        if launcher is not None:
            self._launcher_cache.move_to_end(key)
            return launcher
        self._last["compilations"] += 1
        searcher = ns._searcher()
        spec, radius, k, tile = (ns.spec, ns.params.radius, ns.params.k,
                                 ns.opts.query_tile)
        for g in groups:
            self._signatures.add((g.w_search, g.skip_test, g.pad_n, tile,
                                  k, False))

        @jax.jit
        def launcher(grid, points, queries_s, perm, sels):
            out_idx = jnp.full((nq, k), -1, jnp.int32)
            out_d2 = jnp.full((nq, k), jnp.inf, jnp.float32)
            out_cnt = jnp.zeros((nq,), jnp.int32)
            for (w, skip, _pad_n), sel in zip(metas, sels):
                # sel arrives edge-padded to the bucket: padded slots repeat
                # the group's last real query, so their searched rows are
                # identical to that query's row and the duplicate scatter
                # writes below are idempotent
                qb = queries_s[sel]
                idx, d2, cnt = searcher(grid, points, qb, spec, w, radius,
                                        k, skip, tile)
                orig = perm[sel]
                out_idx = out_idx.at[orig].set(idx)
                out_d2 = out_d2.at[orig].set(d2)
                out_cnt = out_cnt.at[orig].set(cnt)
            return out_idx, out_d2, out_cnt

        self._launcher_cache[key] = launcher
        if len(self._launcher_cache) > _LAUNCHER_CACHE_MAX:
            self._launcher_cache.popitem(last=False)
        return launcher

    def _dispatch_loop(self, groups, queries_s, perm, qcells, nq: int,
                       k: int):
        """Per-group async dispatch (Pallas path): each launch needs host
        tile-anchor metadata from the plan fetch, so the schedule cannot be
        a single jitted program — but every dispatch is still non-blocking
        with on-device scatter."""
        ns = self.ns
        out_idx = jnp.full((nq, k), -1, jnp.int32)
        out_d2 = jnp.full((nq, k), jnp.inf, jnp.float32)
        out_cnt = jnp.zeros((nq,), jnp.int32)
        searcher = ns._searcher()
        for g in groups:
            n_b = g.sel.shape[0]
            sel_dev = jnp.asarray(g.sel, jnp.int32)
            qb = queries_s[sel_dev]
            qb = jnp.pad(qb, ((0, g.pad_n - n_b), (0, 0)), mode="edge")
            kw = {}
            if qcells is not None:
                qc = qcells[g.sel]
                qc = np.pad(qc, ((0, g.pad_n - n_b), (0, 0)), mode="edge")
                kw["qcells"] = qc
            sig = (g.w_search, g.skip_test, g.pad_n, ns.opts.query_tile,
                   k, ns.opts.use_pallas)
            if sig not in self._signatures:
                self._signatures.add(sig)
                self._last["compilations"] += 1
            idx, d2, cnt = searcher(
                ns.grid, ns.points, qb, ns.spec,
                g.w_search, ns.params.radius, k,
                g.skip_test, ns.opts.query_tile, **kw)
            orig = perm[sel_dev]
            out_idx = out_idx.at[orig].set(idx[:n_b])
            out_d2 = out_d2.at[orig].set(d2[:n_b])
            out_cnt = out_cnt.at[orig].set(cnt[:n_b])
            self._last["dispatches"] += 1
        return out_idx, out_d2, out_cnt

    # -- execution ----------------------------------------------------------

    def execute(self, queries) -> SearchResult:
        ns = self.ns
        self._last = dict(host_syncs=0, plan_fetches=0, launches=0,
                          dispatches=0, compilations=0, bundles=0,
                          plan_cache_hit=False)
        t0 = time.perf_counter()
        queries = jnp.asarray(queries, jnp.float32)
        nq = queries.shape[0]
        k = ns.params.k

        perm, _inv = ns._schedule(queries)
        queries_s = queries[perm]
        plan, bundles, groups, qcells = self._plan(queries_s)
        ns.report.t_opt = time.perf_counter() - t0
        ns.report.num_partitions = plan.num_partitions
        ns.report.bundles = bundles
        self._last["bundles"] = len(bundles)
        self._last["launches"] = len(groups)

        t0 = time.perf_counter()
        launcher = self._get_launcher(groups, nq)
        if launcher is not None:
            # edge-pad each selection to its bucket so the launcher only
            # ever sees bucketed shapes (zero retraces on count drift)
            sels = tuple(jnp.asarray(
                np.pad(g.sel, (0, g.pad_n - g.sel.shape[0]), mode="edge"),
                jnp.int32) for g in groups)
            out_idx, out_d2, out_cnt = launcher(
                ns.grid, ns.points, queries_s, perm, sels)
            self._last["dispatches"] = 1
        else:
            out_idx, out_d2, out_cnt = self._dispatch_loop(
                groups, queries_s, perm, qcells, nq, k)

        # one-sync contract: the single blocking materialization
        jax.block_until_ready((out_idx, out_d2, out_cnt))
        self._last["host_syncs"] += 1
        ns.report.t_search = time.perf_counter() - t0
        ns.report.launches = self._last["launches"]
        ns.report.host_syncs = self._last["host_syncs"]
        ns.report.plan_fetches = self._last["plan_fetches"]

        self._totals["queries"] += 1
        for key in ("launches", "dispatches", "bundles", "host_syncs",
                    "plan_fetches", "compilations"):
            self._totals[key] += self._last[key]
        self._totals["plan_cache_hits"] += int(self._last["plan_cache_hit"])

        return SearchResult(indices=out_idx, distances2=out_d2,
                            counts=out_cnt)

    # -- surface ------------------------------------------------------------

    def warmup(self, queries) -> dict:
        """Run one query to populate the plan and compile caches (SPH-style
        steppers call this once before the timed loop). Returns stats()."""
        self.execute(queries)
        return self.stats()

    def stats(self) -> dict:
        """Counters for the caching/sync contract.

        ``last`` holds the most recent query's breakdown; ``compilations``
        counts first-seen launch signatures (the jit cache compiles once per
        signature); ``jit_cache_sizes`` exposes the actual jit caches so
        tests can assert a steady-state query compiled nothing.
        """
        sizes = {}
        try:
            from .search import window_search
            sizes["window_search"] = window_search._cache_size()
        except AttributeError:                      # pragma: no cover
            pass
        if self.ns.opts.use_pallas:
            try:
                from ..kernels.knn_tile import knn_tile
                sizes["knn_tile"] = knn_tile._cache_size()
            except AttributeError:                  # pragma: no cover
                pass
        return {
            **{k: int(v) for k, v in self._totals.items()},
            "last": dict(self._last),
            "signatures": len(self._signatures),
            "plan_cache_entries": len(self._plan_cache),
            "launcher_cache_entries": len(self._launcher_cache),
            "jit_cache_sizes": sizes,
        }
