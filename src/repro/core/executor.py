"""Device-resident bundle executor (DESIGN.md section 3).

The legacy orchestrator (``NeighborSearch._query_host_loop``) ran a Python
loop over bundles with a blocking ``jax.device_get`` + numpy scatter per
bundle — giving back on the host most of what scheduling/partitioning won
on the device, exactly the naive-mapping overhead the paper warns about.
The executor keeps the whole execution phase device-resident:

  * **signature batching** — bundles sharing a static launch signature
    ``(w_search, skip_test, padded-N bucket)`` are folded into one padded
    launch with concatenated segment metadata, so B bundles become
    ~|unique signatures| dispatches instead of B;
  * **async dispatch + on-device scatter** — the whole launch schedule
    (per group: gather -> padded search -> scatter through the composed
    schedule∘partition permutation with ``.at[].set``) runs as ONE jitted
    program with donated output buffers, on BOTH the jnp and the Pallas
    path (the fused kernel's tile-window anchors are computed on device —
    ``kernels/ops.window_search_segmented`` — so no launch needs host
    metadata). No per-bundle ``device_get``, no numpy scatter;
  * **one-sync contract** — exactly ONE blocking host sync materializes
    the results (``jax.block_until_ready`` over the three output arrays).
    The only other host transfer is the *plan fetch*: one fused
    ``device_get`` of the per-query partition metadata (w_search / skip /
    rho) that data-dependent partitioning requires, mirroring the paper's
    host-side launch orchestration. Both are counted in ``stats()``;
  * **plan + compile caching** — host partition/bundle plans are cached
    by value fingerprint and compiled searchers are cached per launch
    signature (the jit cache does the compiling; the executor tracks
    first-seen signatures and jit cache sizes so ``stats()`` can prove a
    steady-state query recompiles nothing).
"""
from __future__ import annotations

import collections
import hashlib
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..reliability import faults
from .bundle import bundle_query_sel
from .partition import (PartitionPlan, compute_megacells,
                        inflate_plan_inputs, plan_partitions, trivial_plan)
from .schedule import schedule_cells
from .types import Array, SearchResult

_PLAN_CACHE_MAX = 32
_LAUNCHER_CACHE_MAX = 32


def _fingerprint(*arrays: np.ndarray) -> bytes:
    h = hashlib.sha1()
    for a in arrays:
        h.update(np.ascontiguousarray(a).tobytes())
    return h.digest()


class LaunchGroup:
    """One padded device launch covering every bundle of one signature."""

    __slots__ = ("w_search", "skip_test", "sel", "pad_n", "n_bundles")

    def __init__(self, w_search: int, skip_test: bool, sel: np.ndarray,
                 pad_n: int, n_bundles: int):
        self.w_search = w_search
        self.skip_test = skip_test
        self.sel = sel              # scheduled-order query positions
        self.pad_n = pad_n
        self.n_bundles = n_bundles


class PlanHandle:
    """A captured schedule∘partition∘bundle plan, replayable across frames.

    Produced by ``QueryExecutor.capture_plan`` and replayed with
    ``execute(queries, reuse=handle)``: the handle owns the Morton schedule
    permutation (device), the partition plan and launch groups, and the
    edge-padded per-group selection vectors (device, uploaded once).
    Replaying performs ZERO host-side planning: no schedule, no plan fetch,
    no partition/bundle recompute, no padding work. The dynamic-scene
    session (``core/dynamic.py``) holds one handle per plan anchor and
    replays it while the max-displacement statistic stays below threshold;
    ``margin`` records the window inflation baked into the plan (the
    staleness contract, ``partition.inflate_plan_inputs``).
    """

    __slots__ = ("perm", "plan", "bundles", "groups", "sels_dev",
                 "nq", "margin")

    def __init__(self, perm, plan, bundles, groups, sels_dev, nq, margin):
        self.perm = perm
        self.plan = plan
        self.bundles = bundles
        self.groups = groups
        self.sels_dev = sels_dev
        self.nq = nq
        self.margin = margin


class PendingResult:
    """A dispatched-but-unsynced query (``QueryExecutor.execute_async``).

    The launch schedule is already in flight on the device; ``wait()``
    performs the one-sync-contract blocking materialization (idempotent —
    repeated calls return the same ``SearchResult``). Letting the caller
    defer the sync is what enables multi-batch pipelining: stage and
    dispatch batch N+1 on the host while batch N executes, then wait on
    N — the serving drain loop's dispatch-then-stage contract
    (``repro.serve``, DESIGN.md section 10).
    """

    __slots__ = ("_executor", "_arrays", "_last", "_sp_query", "_t_launch",
                 "_result")

    def __init__(self, executor, arrays, last, sp_query, t_launch):
        self._executor = executor
        self._arrays = arrays
        self._last = last
        self._sp_query = sp_query
        self._t_launch = t_launch
        self._result: SearchResult | None = None

    def done(self) -> bool:
        return self._result is not None

    def wait(self) -> SearchResult:
        if self._result is None:
            self._result = self._executor._finalize(
                self._arrays, self._last, self._sp_query, self._t_launch)
        return self._result


class QueryExecutor:
    """Executes a ``NeighborSearch``'s bundle plan device-resident.

    Owned by the search object (``ns.executor``); reusable across queries —
    steady-state repeated queries hit the plan cache and compile nothing.
    Surface: ``execute()`` (called by ``NeighborSearch.query``),
    ``capture_plan()``/``execute(reuse=...)`` (the dynamic-scene session),
    ``invalidate()`` (respec), ``warmup()``, ``stats()``.
    """

    def __init__(self, ns):
        self.ns = ns
        self._plan_cache: collections.OrderedDict = collections.OrderedDict()
        self._launcher_cache: collections.OrderedDict = \
            collections.OrderedDict()
        self._signatures: set = set()
        # the totals live in the unified registry (repro.obs): counters for
        # the caching/sync contract, histograms for latency percentiles
        self._metrics = obs.metric_set("executor")
        self._last: dict = {}

    # -- planning -----------------------------------------------------------

    def _plan(self, queries_s: Array, margin: int = 0):
        """Fetch partition metadata (ONE fused device_get), then plan and
        group on host — or reuse a cached plan for this fingerprint.

        ``margin`` inflates every per-query window by that many cells
        (clamped to w_full) before partitioning — the staleness allowance a
        capture-for-reuse plan carries (``partition.inflate_plan_inputs``).
        """
        ns = self.ns
        nq = queries_s.shape[0]
        partitioned = ns.opts.partition and ns.statics.has_megacells

        if partitioned:
            w_dev, s_dev, r_dev = compute_megacells(
                ns.grid, queries_s, ns.statics, ns.params)
            w_np, s_np, r_np = (np.asarray(a) for a in jax.device_get(
                (w_dev, s_dev, r_dev)))
            self._last["plan_fetches"] += 1
            if margin:
                w_np, s_np = inflate_plan_inputs(
                    w_np, s_np, margin=margin, w_full=ns.statics.w_full,
                    w_sph=ns.statics.w_sph)
            key = (nq, margin, _fingerprint(w_np, s_np, r_np))
        else:
            key = (nq, margin, b"nopart")

        hit = self._plan_cache.get(key)
        if hit is not None:
            self._plan_cache.move_to_end(key)
            self._last["plan_cache_hit"] = True
            plan, bundles, groups = hit
            return plan, bundles, groups

        plan = (plan_partitions(w_np, s_np, r_np, ns.statics.w_full)
                if partitioned else trivial_plan(nq, ns.statics.w_full))
        bundles = ns._bundle(plan)
        groups = self._build_groups(plan, bundles)
        self._plan_cache[key] = (plan, bundles, groups)
        if len(self._plan_cache) > _PLAN_CACHE_MAX:
            self._plan_cache.popitem(last=False)
        return plan, bundles, groups

    def _prepare_launch(self, groups):
        """Edge-pad each group's selection to its bucket (device)."""
        return tuple(jnp.asarray(
            np.pad(g.sel, (0, g.pad_n - g.sel.shape[0]), mode="edge"),
            jnp.int32) for g in groups)

    def capture_plan(self, queries, *, qcells_dev: Array | None = None,
                     margin: int = 0) -> PlanHandle:
        """Schedule + partition + bundle ``queries`` once and freeze the
        result into a replayable :class:`PlanHandle`.

        ``qcells_dev`` optionally supplies the queries' device cell
        coordinates (the self-query fast path reuses the grid update's
        binning); ``margin`` bakes the staleness allowance into every
        window so the handle stays exact while displacements remain under
        the session threshold.
        """
        ns = self.ns
        self._last = collections.Counter()    # scratch for _plan's counters
        queries = jnp.asarray(queries, jnp.float32)
        nq = queries.shape[0]
        with obs.span("plan", capture=True, nq=nq, margin=margin) as sp:
            if not ns.opts.schedule:
                perm = jnp.arange(nq, dtype=jnp.int32)
            elif qcells_dev is not None:
                perm, _ = schedule_cells(qcells_dev)
            else:
                perm, _ = ns._schedule(queries)
            queries_s = queries[perm]
            plan, bundles, groups = self._plan(queries_s, margin=margin)
            sels_dev = self._prepare_launch(groups)
        self._metrics.count("plan_fetches", self._last["plan_fetches"])
        self._metrics.count("plan_captures")
        self._metrics.observe("plan_s", sp.duration)
        return PlanHandle(perm=perm, plan=plan, bundles=bundles,
                          groups=groups, sels_dev=sels_dev, nq=nq,
                          margin=margin)

    def _build_groups(self, plan: PartitionPlan,
                      bundles) -> list[LaunchGroup]:
        """Fold bundles sharing (w_search, skip_test) into one launch."""
        from .search import _pad_bucket

        by_sig: dict = {}
        order: list = []
        for b in bundles:
            sig = b.signature
            if sig not in by_sig:
                by_sig[sig] = []
                order.append(sig)
            by_sig[sig].append(bundle_query_sel(plan, b))
        groups = []
        for sig in order:
            sels = by_sig[sig]
            sel = (sels[0] if len(sels) == 1
                   else np.concatenate(sels)).astype(np.int64)
            groups.append(LaunchGroup(
                w_search=sig[0], skip_test=sig[1], sel=sel,
                pad_n=_pad_bucket(sel.shape[0], self.ns.opts.query_tile),
                n_bundles=len(sels)))
        return groups

    # -- compiled launch schedules ------------------------------------------

    def _get_launcher(self, groups, nq: int):
        """One jitted program running the WHOLE launch schedule: per group
        gather -> padded window search -> on-device scatter through the
        composed schedule∘partition permutation. Cached by the plan's
        *padded-bucket* shape ``(w, skip, pad_n)`` per group, NOT by exact
        query counts or plan values: the selection vector is edge-padded to
        the bucket on the host, so steady-state queries whose partition
        counts drift within the same buckets (SPH stepping) reuse the
        compiled schedule unchanged.

        Covers the Pallas path too: ``window_search_pallas`` is pure
        traced JAX (tile-window anchors computed on device via the
        level-segmented launches of ``kernels/ops``), so the fused kernels
        compile INTO the launch schedule. The three output buffers are
        donated — the caller hands in fresh init arrays and XLA scatters
        into them in place instead of materializing copies.
        """
        ns = self.ns
        metas = tuple((g.w_search, g.skip_test, g.pad_n) for g in groups)
        key = (metas, nq, ns.params.k, ns.opts.query_tile,
               ns.opts.use_pallas)
        launcher = self._launcher_cache.get(key)
        if launcher is not None:
            self._launcher_cache.move_to_end(key)
            self._last["launcher_cache_hit"] = True
            return launcher
        faults.maybe_fail("compile")
        self._last["compilations"] += 1
        searcher = ns._searcher()
        spec, radius, k, tile = (ns.spec, ns.params.radius, ns.params.k,
                                 ns.opts.query_tile)
        for g in groups:
            self._signatures.add((g.w_search, g.skip_test, g.pad_n, tile,
                                  k, ns.opts.use_pallas))

        @partial(jax.jit, donate_argnums=(5, 6, 7))
        def launcher(grid, points, queries_s, perm, sels,
                     out_idx, out_d2, out_cnt):
            for (w, skip, _pad_n), sel in zip(metas, sels):
                # sel arrives edge-padded to the bucket: padded slots repeat
                # the group's last real query, so their searched rows are
                # identical to that query's row and the duplicate scatter
                # writes below are idempotent
                qb = queries_s[sel]
                idx, d2, cnt = searcher(grid, points, qb, spec, w, radius,
                                        k, skip, tile)
                orig = perm[sel]
                out_idx = out_idx.at[orig].set(idx)
                out_d2 = out_d2.at[orig].set(d2)
                out_cnt = out_cnt.at[orig].set(cnt)
            return out_idx, out_d2, out_cnt

        self._launcher_cache[key] = launcher
        if len(self._launcher_cache) > _LAUNCHER_CACHE_MAX:
            self._launcher_cache.popitem(last=False)
        return launcher

    # -- execution ----------------------------------------------------------

    def execute(self, queries, *,
                reuse: PlanHandle | None = None) -> SearchResult:
        """Run one query. With ``reuse`` the given captured plan is replayed
        verbatim — no schedule, no plan fetch, no partition/bundle work, no
        padding: pure device dispatch through the cached compiled launch
        schedule (the dynamic-scene steady state)."""
        return self.execute_async(queries, reuse=reuse).wait()

    def execute_async(self, queries, *,
                      reuse: PlanHandle | None = None) -> "PendingResult":
        """Plan and dispatch one query WITHOUT the blocking result sync.

        Returns a :class:`PendingResult` whose ``wait()`` performs the
        one-sync materialization. Splitting dispatch from sync lets a
        streaming caller (the serving drain loop, an SPH stepper over many
        independent batches) stage batch N+1 on the host while batch N
        still executes on device — the pipelining the one-sync contract
        otherwise serializes away. Overlap-safe: every per-call counter
        rides the pending record, not executor scratch state.
        """
        ns = self.ns
        last = dict(host_syncs=0, plan_fetches=0, launches=0,
                    dispatches=0, compilations=0, bundles=0,
                    plan_cache_hit=False, plan_reused=False,
                    launcher_cache_hit=False)
        self._last = last
        queries = jnp.asarray(queries, jnp.float32)
        nq = queries.shape[0]
        k = ns.params.k

        # the top-level query span stays open until the pending result's
        # wait() — plan/launch/sync all nest under it, preserving the
        # section-9 span taxonomy across the dispatch/sync split
        sp_query = obs.span("query", nq=nq)
        sp_query.__enter__()
        try:
            # fault-injection seam (reliability.faults): a scheduled
            # launch fault fails the dispatch before any device work
            faults.maybe_fail("launch")
            return self._dispatch_pending(queries, nq, k, reuse, last,
                                          sp_query)
        except BaseException:
            sp_query.__exit__(None, None, None)
            raise

    def _dispatch_pending(self, queries, nq, k, reuse, last, sp_query):
        ns = self.ns
        with obs.span("plan", reused=reuse is not None) as sp_plan:
            if reuse is not None:
                if reuse.nq != nq:
                    raise ValueError(f"reused plan was captured for nq="
                                     f"{reuse.nq}, got {nq} queries")
                perm = reuse.perm
                queries_s = queries[perm]
                plan, bundles, groups = (reuse.plan, reuse.bundles,
                                         reuse.groups)
                sels_dev = reuse.sels_dev
                last["plan_reused"] = True
            else:
                perm, _inv = ns._schedule(queries)
                queries_s = queries[perm]
                plan, bundles, groups = self._plan(queries_s)
                sels_dev = self._prepare_launch(groups)
        ns.report.t_opt = sp_plan.duration
        ns.report.num_partitions = plan.num_partitions
        ns.report.bundles = bundles
        last["bundles"] = len(bundles)
        last["launches"] = len(groups)

        t0 = time.perf_counter()
        with obs.span("launch", groups=len(groups)):
            launcher = self._get_launcher(groups, nq)
            # selections are edge-padded to their buckets so the
            # launcher only ever sees bucketed shapes (zero retraces on
            # count drift); the freshly-initialized output buffers are
            # donated into the program
            t_disp = time.perf_counter()
            out_idx, out_d2, out_cnt = launcher(
                ns.grid, ns.points, queries_s, perm, sels_dev,
                jnp.full((nq, k), -1, jnp.int32),
                jnp.full((nq, k), jnp.inf, jnp.float32),
                jnp.zeros((nq,), jnp.int32))
            if last["compilations"]:
                # the jit compile happened inside that first dispatch
                obs.record_span("compile", time.perf_counter() - t_disp)
        last["dispatches"] = 1
        return PendingResult(self, (out_idx, out_d2, out_cnt), last,
                             sp_query, t0)

    def _finalize(self, arrays, last, sp_query, t_launch) -> SearchResult:
        """The pending result's one blocking sync + metric/report flush."""
        ns = self.ns
        out_idx, out_d2, out_cnt = arrays
        faults.maybe_delay()          # injected straggler: sync is late
        with obs.span("sync"):
            jax.block_until_ready(arrays)
        sp_query.__exit__(None, None, None)
        last["host_syncs"] += 1
        ns.report.t_search = time.perf_counter() - t_launch
        ns.report.launches = last["launches"]
        ns.report.host_syncs = last["host_syncs"]
        ns.report.plan_fetches = last["plan_fetches"]
        self._last = last

        m = self._metrics
        m.count("queries")
        for key in ("launches", "dispatches", "bundles", "host_syncs",
                    "plan_fetches", "compilations"):
            m.count(key, last[key])
        m.count("plan_cache_hits", int(last["plan_cache_hit"]))
        m.count("plan_cache_misses",
                int(not (last["plan_cache_hit"] or last["plan_reused"])))
        m.count("plan_reuses", int(last["plan_reused"]))
        m.count("launcher_cache_hits", int(last["launcher_cache_hit"]))
        m.count("launcher_cache_misses", last["compilations"])
        m.observe("query_s", sp_query.duration)
        m.observe("plan_s", ns.report.t_opt)
        m.gauge("plan_cache_entries", len(self._plan_cache))
        m.gauge("launcher_cache_entries", len(self._launcher_cache))

        return SearchResult(indices=out_idx, distances2=out_d2,
                            counts=out_cnt)

    def invalidate(self) -> None:
        """Drop every cached plan, compiled launch schedule, and signature.

        A respec (``core/dynamic.py``) changes the grid spec that cached
        launchers close over and that every plan was computed against —
        replaying any of them would search the wrong geometry, so the
        caches are cleared wholesale and outstanding ``PlanHandle``s must
        be discarded by their owner."""
        self._plan_cache.clear()
        self._launcher_cache.clear()
        self._signatures.clear()
        self._metrics.count("invalidations")

    # -- surface ------------------------------------------------------------

    def warmup(self, queries) -> dict:
        """Run one query to populate the plan and compile caches (SPH-style
        steppers call this once before the timed loop). Returns stats()."""
        self.execute(queries)
        return self.stats()

    def stats(self) -> dict:
        """Counters for the caching/sync contract.

        ``last`` holds the most recent query's breakdown; ``compilations``
        counts first-seen launch signatures (the jit cache compiles once per
        signature); ``jit_cache_sizes`` exposes the actual jit caches so
        tests can assert a steady-state query compiled nothing.
        """
        sizes = {}
        try:
            from .search import window_search
            sizes["window_search"] = window_search._cache_size()
        except AttributeError:                      # pragma: no cover
            pass
        if self.ns.opts.use_pallas:
            try:
                from ..kernels.knn_tile import knn_tile, knn_tile_anchored
                sizes["knn_tile"] = knn_tile._cache_size()
                sizes["knn_tile_anchored"] = knn_tile_anchored._cache_size()
            except AttributeError:                  # pragma: no cover
                pass
        return {
            **self._metrics.counters(),
            "last": dict(self._last),
            "signatures": len(self._signatures),
            "plan_cache_entries": len(self._plan_cache),
            "launcher_cache_entries": len(self._launcher_cache),
            "jit_cache_sizes": sizes,
        }
