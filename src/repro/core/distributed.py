"""Distributed neighbor search: spatial decomposition + halo exchange.

Maps the paper's single-GPU algorithm onto a JAX device mesh
(DESIGN.md section 6):

  * ``slab_axis`` ("data"): the domain is cut into equal-width x-slabs, one
    per mesh row. Each row owns its slab's points; boundary points within
    ``radius`` of a slab face are exchanged with the two spatial neighbors
    via ``jax.lax.ppermute`` — O(surface), not O(volume), communication.
  * ``query_axis`` ("model"): queries routed to a slab are split across the
    mesh columns (queries are independent — the paper's own observation —
    so this axis is embarrassingly parallel).
  * a ``pod`` axis, when present, replicates the structure and splits query
    batches: pure throughput scaling.

Equal-width slabs keep the per-shard grid spec static (one trace serves all
shards); per-slab origins are dynamic arrays.

Query routing happens on the host (np.digitize bucketing + padding),
mirroring the paper's host-side orchestration; results come back in the
original query order with *global* point indices.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax >= 0.5 promotes shard_map to jax.shard_map and renames the replication
# check kwarg check_rep -> check_vma; this repo must run on both.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}

from .grid import build_cell_grid
from .search import window_search
from .types import GridSpec, SearchParams, SearchResult

_SENTINEL = 1e30  # parks padded points/queries far outside any slab


@dataclasses.dataclass
class SlabPlan:
    """Host-side layout of the spatial decomposition."""

    n_slabs: int
    n_qsplit: int
    lo_x: float
    slab_width: float
    point_cap: int          # max points per slab (incl. padding)
    halo_cap: int           # max boundary points exchanged per side
    query_cap: int          # max queries per (slab, qsplit) cell
    spec: GridSpec          # local grid spec (shared; origin is per-slab)


def plan_slabs(points: np.ndarray, queries: np.ndarray, radius: float,
               n_slabs: int, n_qsplit: int,
               cell_size: float | None = None) -> SlabPlan:
    points = np.asarray(points, np.float32)
    queries = np.asarray(queries, np.float32)
    lo, hi = points[:, 0].min(), points[:, 0].max()
    width = max((hi - lo) / n_slabs, 1e-6)
    cell = cell_size or max(radius, 1e-6)

    slab_of_p = np.clip(((points[:, 0] - lo) / width).astype(int), 0,
                        n_slabs - 1)
    slab_of_q = np.clip(((queries[:, 0] - lo) / width).astype(int), 0,
                        n_slabs - 1)
    p_cnt = np.bincount(slab_of_p, minlength=n_slabs)
    q_cnt = np.bincount(slab_of_q, minlength=n_slabs)

    # halo capacity: points within radius of either face of their slab
    rel = points[:, 0] - (lo + slab_of_p * width)
    boundary = (rel <= radius) | (rel >= width - radius)
    h_cnt = np.bincount(slab_of_p[boundary], minlength=n_slabs)

    # local grid: slab + halo margins, same static dims for every slab
    yz_lo = points[:, 1:].min(axis=0)
    yz_hi = points[:, 1:].max(axis=0)
    dims = (
        int(math.ceil((width + 2 * radius) / cell)) + 3,
        int(math.ceil(max(yz_hi[0] - yz_lo[0], 1e-6) / cell)) + 3,
        int(math.ceil(max(yz_hi[1] - yz_lo[1], 1e-6) / cell)) + 3,
    )
    # capacity: worst-case cell occupancy across the whole domain is a safe
    # (over-)estimate for every local grid
    gc = np.floor((points - np.concatenate([[lo - radius - cell],
                                            yz_lo - cell])) / cell)
    gc = gc.astype(np.int64)
    flat = (gc[:, 0] * (dims[1] + 64) + gc[:, 1]) * (dims[2] + 64) + gc[:, 2]
    _, occ = np.unique(flat, return_counts=True)
    cap = int(occ.max())

    qcap = int(np.ceil(q_cnt.max() / n_qsplit)) if len(q_cnt) else 1
    return SlabPlan(
        n_slabs=n_slabs,
        n_qsplit=n_qsplit,
        lo_x=float(lo),
        slab_width=float(width),
        point_cap=int(p_cnt.max()),
        halo_cap=int(max(h_cnt.max(), 1)),
        query_cap=max(qcap, 1),
        spec=GridSpec(origin=(0.0, 0.0, 0.0), cell_size=float(cell),
                      dims=dims, capacity=max(cap, 1)),
    )


def _route(plan: SlabPlan, points: np.ndarray, queries: np.ndarray):
    """Host-side bucketing into fixed-capacity per-shard arrays."""
    n, q = points.shape[0], queries.shape[0]
    slab_of_p = np.clip(((points[:, 0] - plan.lo_x) / plan.slab_width)
                        .astype(int), 0, plan.n_slabs - 1)
    slab_of_q = np.clip(((queries[:, 0] - plan.lo_x) / plan.slab_width)
                        .astype(int), 0, plan.n_slabs - 1)

    pts = np.full((plan.n_slabs, plan.point_cap, 3), _SENTINEL, np.float32)
    ids = np.full((plan.n_slabs, plan.point_cap), -1, np.int32)
    for s in range(plan.n_slabs):
        sel = np.where(slab_of_p == s)[0]
        pts[s, : len(sel)] = points[sel]
        ids[s, : len(sel)] = sel

    qs = np.full((plan.n_slabs, plan.n_qsplit, plan.query_cap, 3),
                 _SENTINEL, np.float32)
    qid = np.full((plan.n_slabs, plan.n_qsplit, plan.query_cap), -1, np.int32)
    for s in range(plan.n_slabs):
        sel = np.where(slab_of_q == s)[0]
        parts = np.array_split(sel, plan.n_qsplit)
        for c, pp in enumerate(parts):
            qs[s, c, : len(pp)] = queries[pp]
            qid[s, c, : len(pp)] = pp
    return pts, ids, qs, qid


def _halo_select(pts, ids, face_dist, radius: float, cap: int):
    """Pick up to ``cap`` points within ``radius`` of a slab face
    (static-shape: order by boundary-ness, take first cap)."""
    is_b = (face_dist <= radius) & (ids >= 0)
    order = jnp.argsort(jnp.where(is_b, 0, 1), stable=True)[:cap]
    sel_p = pts[order]
    sel_i = ids[order]
    valid = is_b[order]
    sel_p = jnp.where(valid[:, None], sel_p, _SENTINEL)
    sel_i = jnp.where(valid, sel_i, -1)
    return sel_p, sel_i


def make_distributed_search(mesh: Mesh, plan: SlabPlan,
                            params: SearchParams,
                            slab_axis: str = "data",
                            query_axis: str = "model",
                            tile: int = 128):
    """Build the jitted shard_map search over ``mesh``.

    Returned fn: (pts [S,P,3], ids [S,P], qs [S,C,Q,3]) ->
    (idx [S,C,Q,K] global ids, d2, counts). Extra leading mesh axes (e.g.
    "pod") must already be folded into the inputs by the caller.
    """
    spec = plan.spec
    n_slabs = plan.n_slabs
    radius, k = params.radius, params.k
    w_full = max(1, int(math.ceil(radius / spec.cell_size - 1e-6)))

    def local_fn(pts, ids, qs):
        pts, ids, qs = pts[0], ids[0], qs[0, 0]       # shard-local views
        sidx = jax.lax.axis_index(slab_axis)
        origin_x = plan.lo_x + sidx * plan.slab_width - radius \
            - spec.cell_size
        origin = jnp.stack([
            origin_x,
            jnp.float32(spec.origin[1]),
            jnp.float32(spec.origin[2]),
        ])

        # --- halo exchange (left and right spatial neighbors) -------------
        slab_lo = plan.lo_x + sidx * plan.slab_width
        slab_hi = slab_lo + plan.slab_width
        send_l_p, send_l_i = _halo_select(
            pts, ids, pts[:, 0] - slab_lo, radius, plan.halo_cap)
        send_r_p, send_r_i = _halo_select(
            pts, ids, slab_hi - pts[:, 0], radius, plan.halo_cap)
        # ids are shifted +1 so a zero-filled (edge) permute decodes to -1
        pack = lambda p, i: jnp.concatenate(
            [p, (i + 1)[:, None].astype(jnp.float32)], axis=1)
        right_perm = [(i, i + 1) for i in range(n_slabs - 1)]
        left_perm = [(i + 1, i) for i in range(n_slabs - 1)]
        from_left = jax.lax.ppermute(pack(send_r_p, send_r_i), slab_axis,
                                     right_perm)
        from_right = jax.lax.ppermute(pack(send_l_p, send_l_i), slab_axis,
                                      left_perm)

        def unpack(buf):
            i = buf[:, 3].astype(jnp.int32) - 1
            p = jnp.where((i >= 0)[:, None], buf[:, :3], _SENTINEL)
            return p, i

        halo_l_p, halo_l_i = unpack(from_left)
        halo_r_p, halo_r_i = unpack(from_right)

        all_p = jnp.concatenate([pts, halo_l_p, halo_r_p], axis=0)
        all_i = jnp.concatenate([ids, halo_l_i, halo_r_i], axis=0)

        # --- local structure build + search ------------------------------
        # positions stay in the GLOBAL frame (bit-identical distances to the
        # single-device oracle); only the cell lookup uses the dynamic
        # per-slab origin. Invalid points are parked far away so they land
        # in the clamped corner cell with sentinel distances.
        safe_p = jnp.where((all_i >= 0)[:, None], all_p, _SENTINEL)
        grid = build_cell_grid(safe_p, spec, origin)
        idx, d2, cnt = window_search(
            grid, safe_p, qs, spec, w_full, radius, k, False, tile,
            origin=origin)
        # local row -> global point id; sentinel-padded rows never match
        gidx = jnp.where(idx >= 0, all_i[jnp.clip(idx, 0)], -1)
        # a halo row could be a duplicate of a pad slot: drop id -1 hits
        d2 = jnp.where(gidx >= 0, d2, jnp.inf)
        cnt = jnp.sum((gidx >= 0).astype(jnp.int32), axis=-1)
        return gidx[None, None], d2[None, None], cnt[None, None]

    in_specs = (P(slab_axis, None, None), P(slab_axis, None),
                P(slab_axis, query_axis, None, None))
    out_specs = (P(slab_axis, query_axis, None, None),
                 P(slab_axis, query_axis, None, None),
                 P(slab_axis, query_axis, None))
    fn = _shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs, **_SHARD_MAP_KW)
    return jax.jit(fn)


def distributed_neighbor_search(mesh: Mesh, points, queries,
                                params: SearchParams,
                                slab_axis: str = "data",
                                query_axis: str = "model",
                                cell_size: float | None = None
                                ) -> SearchResult:
    """One-shot convenience API: plan, route, search, un-route."""
    points = np.asarray(points, np.float32)
    queries = np.asarray(queries, np.float32)
    n_slabs = mesh.shape[slab_axis]
    n_qsplit = mesh.shape[query_axis]
    plan = plan_slabs(points, queries, params.radius, n_slabs, n_qsplit,
                      cell_size)
    pts, ids, qs, qid = _route(plan, points, queries)
    fn = make_distributed_search(mesh, plan, params, slab_axis, query_axis)
    idx, d2, cnt = jax.device_get(fn(jnp.asarray(pts), jnp.asarray(ids),
                                     jnp.asarray(qs)))
    nq, k = queries.shape[0], params.k
    out_i = np.full((nq, k), -1, np.int32)
    out_d = np.full((nq, k), np.inf, np.float32)
    out_c = np.zeros((nq,), np.int32)
    flat_qid = qid.reshape(-1)
    valid = flat_qid >= 0
    out_i[flat_qid[valid]] = idx.reshape(-1, k)[valid]
    out_d[flat_qid[valid]] = d2.reshape(-1, k)[valid]
    out_c[flat_qid[valid]] = cnt.reshape(-1)[valid]
    return SearchResult(indices=jnp.asarray(out_i),
                        distances2=jnp.asarray(out_d),
                        counts=jnp.asarray(out_c))
