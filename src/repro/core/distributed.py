"""Distributed neighbor search — thin shim over the sharded-scene
subsystem (``core/shards.py``, DESIGN.md section 6).

The original implementation in this module routed points and queries on
the host (``np.digitize`` bucketing + Python scatter loops) on every call
and ran a bespoke full-window search inside ``shard_map``, bypassing the
functional core entirely. All of that now lives — traced — in
``core/shards.py``: on-device slab routing (padded scatter), O(surface)
halo exchange via ``ppermute`` inside ``shard_map(api.query)``, one shared
static ``GridSpec`` across slabs, and the traced inverse scatter. This
module keeps the legacy one-shot convenience surface.

Version compatibility (shard_map location, ``check_rep``/``check_vma``)
is feature-detected in ``shards.py``; ``_shard_map``/``_SHARD_MAP_KW``
are re-exported here for callers that historically imported them from
this module.
"""
from __future__ import annotations

import dataclasses

from jax.sharding import Mesh

from .shards import (_SHARD_MAP_KW, _shard_map,  # noqa: F401 (re-export)
                     STATIC_SCENE_OPTS, shard_scene)
from .types import SearchOpts, SearchParams, SearchResult


def distributed_neighbor_search(mesh: Mesh, points, queries,
                                params: SearchParams,
                                slab_axis: str = "data",
                                query_axis: str = "model",
                                cell_size: float | None = None,
                                opts: SearchOpts = SearchOpts()
                                ) -> SearchResult:
    """One-shot sharded search: plan, route, search, un-route.

    Results come back in query order with *global* point indices, exactly
    as before — but routing and un-routing are now traced device scatters
    and the per-slab search is ``api.query`` over the slab's functional
    ``NeighborIndex`` (megacell partitioning and the Pallas path compose).

    KNN keeps this surface's historical exactness contract: the
    approximate-by-design heuristic window is upgraded to the paper's
    conservative exact window (the legacy implementation always searched
    the full-radius window, so it was exact regardless of ``knn_window``).
    """
    if params.mode == "knn" and params.knn_window != "exact":
        params = dataclasses.replace(params, knn_window="exact")
    index = shard_scene(points, params, mesh=mesh, opts=opts,
                        shopts=STATIC_SCENE_OPTS, queries=queries,
                        cell_size=cell_size, slab_axis=slab_axis,
                        query_axis=query_axis)
    return index.query(queries)
