"""Core datatypes for the RTNN-on-TPU neighbor search library.

Static-shape discipline: everything that determines an array shape (grid
dims, cell capacity, K, window radius, tile sizes) is a Python int held in a
hashable spec object, so jitted functions specialize per spec. Everything
data-dependent (point positions, counts, permutations) lives in arrays.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array

# Padding convention of the sharded slabs (core/shards.py): rows "parked" at
# PARK_SENTINEL are empty slots of a fixed-capacity buffer. Any position with
# a coordinate magnitude >= PARK_THRESHOLD is treated as parked by the
# functional core when ``SearchOpts.mask_parked`` is set: dropped from the
# grid entirely and excluded from the update statistics.
PARK_SENTINEL = 1e30
PARK_THRESHOLD = 1e29


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static description of a uniform cell grid over the point domain.

    The grid is the TPU-native acceleration structure replacing the paper's
    BVH (DESIGN.md section 2): points are binned into cells of edge
    ``cell_size``; a search with window radius ``w`` (in cells) gathers the
    ``(2w+1)**3`` cell neighborhood, the analogue of the set of AABBs whose
    width the paper tunes.
    """

    origin: tuple[float, float, float]
    cell_size: float
    dims: tuple[int, int, int]          # number of cells per axis (static)
    capacity: int                        # max points stored per cell (static)

    @property
    def num_cells(self) -> int:
        dx, dy, dz = self.dims
        return dx * dy * dz

    def cell_of(self, pos: Array, origin: Array | None = None) -> Array:
        """Integer cell coordinates of positions ``pos`` [..., 3].

        ``origin`` optionally overrides the static origin with a dynamic
        array — used by the distributed slabs, whose local frames differ
        per shard while the spec (shapes) is shared.
        """
        o = (jnp.asarray(self.origin, dtype=pos.dtype) if origin is None
             else origin.astype(pos.dtype))
        c = jnp.floor((pos - o) / self.cell_size).astype(jnp.int32)
        hi = jnp.asarray([d - 1 for d in self.dims], dtype=jnp.int32)
        return jnp.clip(c, 0, hi)

    def flat_cell(self, ccoord: Array) -> Array:
        """Flatten [..., 3] integer cell coords to a scalar cell id."""
        _, dy, dz = self.dims
        return (ccoord[..., 0] * dy + ccoord[..., 1]) * dz + ccoord[..., 2]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CellGrid:
    """The built acceleration structure.

    ``dense``    [Dx, Dy, Dz, C]  int32 point indices, -1 padded.
    ``counts``   [Dx, Dy, Dz]     int32 points per cell (clipped to C).
    ``sat``      [Dx+1, Dy+1, Dz+1] int32 3-D summed-area table of counts;
                 box sums in O(1) for the megacell growth of paper section 5.1.
    ``overflow`` scalar int32: number of points dropped because their cell
                 exceeded capacity (0 in a correctly-capacity-planned build;
                 asserted in tests).
    """

    spec: GridSpec
    dense: Array
    counts: Array
    sat: Array
    overflow: Array

    def tree_flatten(self):
        return (self.dense, self.counts, self.sat, self.overflow), self.spec

    @classmethod
    def tree_unflatten(cls, spec, leaves):
        dense, counts, sat, overflow = leaves
        return cls(spec=spec, dense=dense, counts=counts, sat=sat,
                   overflow=overflow)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class UpdateStats:
    """On-device counters of one incremental grid update (all scalar int32 /
    f32 device arrays; fetched in ONE fused transfer per step by the
    session).

    ``overflow``   points dropped because their cell exceeded capacity.
    ``oob``        points whose true cell lies outside the frozen grid —
                   binning them clamped would lose exactness, so any nonzero
                   value triggers the session's respec-and-rebuild fallback.
    ``max_disp2``  max squared displacement vs the plan-anchor positions;
                   compared against the staleness threshold to decide
                   whether the cached schedule/partition plan is reusable.
    """

    overflow: Array
    oob: Array
    max_disp2: Array

    def tree_flatten(self):
        return (self.overflow, self.oob, self.max_disp2), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Static parameters of one neighbor search call."""

    radius: float
    k: int
    mode: str = "knn"                  # "knn" | "range"
    knn_window: str = "heuristic"      # "heuristic" | "exact" (paper 5.1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SearchResult:
    """indices [Nq, K] int32 (-1 pad), distances2 [Nq, K] f32 (inf pad),
    counts [Nq] int32."""

    indices: Array
    distances2: Array
    counts: Array

    def tree_flatten(self):
        return (self.indices, self.distances2, self.counts), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


@dataclasses.dataclass(frozen=True)
class SearchOpts:
    """Which paper optimizations are enabled (benchmark ablation knobs,
    mirroring Fig. 13: NoOpt / Sched / +Partition / +Bundle)."""

    schedule: bool = True              # section 4: Morton query ordering
    partition: bool = True             # section 5.1: megacell partitioning
    bundle: bool = True                # section 5.2: cost-model bundling
    use_pallas: bool = False           # fused kernels (interpret on CPU)
    query_tile: int = 256              # queries per jnp/kernel tile
    w_max: int = 6                     # max megacell growth rings examined
    executor: bool = True              # device-resident QueryExecutor path
    #                                    (False: legacy per-bundle host loop,
    #                                    kept for A/B benchmarking)
    w_ladder: tuple[int, ...] | None = None
    #                                    explicit window ladder for the traced
    #                                    functional path (core/api.py): queries
    #                                    round UP to the nearest ladder window
    #                                    (always exact, sphere test always on);
    #                                    None derives the ladder from the
    #                                    megacell statics. Bounds the traced
    #                                    lax.switch branch count.
    mask_parked: bool = False          # rows parked at PARK_SENTINEL (fixed-
    #                                    capacity slab padding, core/shards.py)
    #                                    are absent: dropped from the grid and
    #                                    excluded from oob/displacement stats
