from .rules import (param_pspecs, opt_pspecs, make_shard_fn, batch_pspec,
                    cache_pspecs, named_sharding_tree, batch_axes)
