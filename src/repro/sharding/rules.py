"""Logical-axis sharding rules -> PartitionSpecs.

Scheme (MaxText-style 2-D: "data" doubles as the FSDP axis, "model" is the
tensor/expert-parallel axis, "pod" — when present — is pure DP):

  params: weight matrices shard (input-dim -> "data", output/head/expert
          dim -> "model") wherever the dim divides the axis; everything
          else replicates. Optimizer moments inherit param specs, giving
          ZeRO-style sharded optimizer state for free.
  activations: batch -> ("pod","data"); heads/ffn/vocab -> "model";
          constraints are emitted only when shapes divide (decode with
          B=1 falls back cleanly).

Every rule checks divisibility against the actual mesh, so one rule set
serves the 16x16 pod mesh, the 2x16x16 multi-pod mesh, and tiny test
meshes.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# leaf-name -> trailing-dims logical roles
#   i = input dim ("data"), o = output dim ("model"), e = experts ("model"),
#   h = heads ("model"), . = replicated
_PARAM_RULES: dict[tuple[str, int], str] = {
    ("embed", 2): "oi",       # [vocab->model, d->data]
    ("unembed", 2): "io",     # [d->data, vocab->model]
    ("wq", 3): "ih.",
    ("wk", 3): "ih.",
    ("wv", 3): "ih.",
    ("wo", 3): "h.i",
    ("w_gate", 2): "io",
    ("w_up", 2): "io",
    ("w_down", 2): "oi",
    # MoE [E, d, ff]: expert-parallel when E divides the model axis;
    # otherwise fall back to tensor-parallel on ff (e.g. grok-1's 8 experts
    # under a 16-way model axis)
    ("w_gate", 3): ("ei.", ".io"),
    ("w_up", 3): ("ei.", ".io"),
    ("w_down", 3): ("e.i", ".oi"),
    ("router", 2): "i.",
    ("q_a", 2): "i.",
    ("q_b", 3): ".h.",
    ("kv_a", 2): "i.",
    ("kv_b", 3): ".h.",
    ("w_x", 2): "io",
    ("w_y", 2): "io",
    ("w_out", 2): "oi",
    ("w_a", 2): ".o",
    ("w_i", 2): ".o",
    ("conv_w", 2): ".o",
    ("wr", 2): "io",
    ("wk", 2): "io",
    ("wv", 2): "io",
    ("wg", 2): "io",
    ("wo", 2): "oi",
    ("w1", 2): "i.",
    ("w2", 2): ".i",
    ("proj", 2): "i.",
}

_ROLE_AXIS = {"i": "data", "o": "model", "h": "model", "e": "model",
              ".": None}


def _axis_size(mesh: Mesh, name: str | None) -> int:
    if name is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def _leaf_name(path) -> str:
    for p in reversed(path):
        k = getattr(p, "key", None)
        if isinstance(k, str):
            return k
    return ""


def param_pspec(path, leaf, mesh: Mesh, profile: str = "train") -> P:
    """``profile="train"``: FSDP("data") x TP("model"), memory-optimal.
    ``profile="serve"``: weights replicated over "data" (serving groups —
    each data row is an independent model replica serving its own batch
    shard), TP("model") only: no per-token FSDP weight all-gathers
    (EXPERIMENTS.md Perf iteration 3). Callers pick "serve" only when the
    model-sharded weights fit HBM (dryrun.build_cell checks)."""
    name = _leaf_name(path)
    nd = leaf.ndim
    # norm scales / biases / 1-D leaves replicate
    for trail in range(nd, 0, -1):
        rules = _PARAM_RULES.get((name, trail))
        if rules is None:
            continue
        if isinstance(rules, str):
            rules = (rules,)
        best, best_score = None, -1
        for rule in rules:
            specs: list[str | None] = [None] * (nd - trail)
            score = 0
            for dim_sz, role in zip(leaf.shape[nd - trail:], rule):
                ax = _ROLE_AXIS[role]
                if profile == "serve" and ax == "data":
                    ax = None
                if ax is not None and (ax not in mesh.axis_names
                                       or dim_sz % _axis_size(mesh, ax)):
                    ax = None
                if ax is not None:
                    score += 1
                specs.append(ax)
            if score > best_score:
                best, best_score = P(*specs), score
        return best
    return P()


def param_pspecs(params: PyTree, mesh: Mesh,
                 profile: str = "train") -> PyTree:
    """PartitionSpec tree matching ``params`` (arrays or ShapeDtypeStructs).
    Optimizer states built with tree.map over params reuse these specs via
    opt_pspecs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params),
        [param_pspec(path, leaf, mesh, profile) for path, leaf in flat])


def opt_pspecs(opt_state_shapes: PyTree, mesh: Mesh) -> PyTree:
    """Specs for the optimizer state: moments named like their params (the
    path contains the param names), quantized leaves (code/scale) shard on
    their block axis over "data" when divisible."""
    flat, _ = jax.tree_util.tree_flatten_with_path(opt_state_shapes)
    specs = []
    for path, leaf in flat:
        name = _leaf_name(path)
        if name == "code":
            # param-shaped int8 codes: inherit the param's spec exactly
            # (path minus the trailing "code" names the param leaf)
            specs.append(param_pspec(path[:-1], leaf, mesh))
        elif name == "scale":
            # param shape with the last axis reduced to n_blocks: the
            # param rule applies and its last-dim axis is dropped if the
            # block count no longer divides
            spec = param_pspec(path[:-1], leaf, mesh)
            dims = list(spec) + [None] * (leaf.ndim - len(spec))
            if dims and dims[-1] is not None:
                ax_names = dims[-1] if isinstance(dims[-1], tuple) \
                    else (dims[-1],)
                n = int(np.prod([mesh.shape[a] for a in ax_names]))
                if leaf.shape[-1] % n:
                    dims[-1] = None
            specs.append(P(*dims))
        elif name == "step":
            specs.append(P())
        else:
            # strip the m/v prefix: the remaining path names the param leaf
            specs.append(param_pspec(path, leaf, mesh))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(opt_state_shapes), specs)


def named_sharding_tree(specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# -- activations -------------------------------------------------------------

def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_pspec(mesh: Mesh, batch_size: int, extra_dims: int = 1) -> P:
    """Spec for a [B, ...] batch array; shards B over pod+data if divisible."""
    axes = batch_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch_size % n == 0:
        return P(axes, *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def make_shard_fn(mesh: Mesh):
    """Activation-constraint callable threaded through the models."""
    baxes = batch_axes(mesh)
    n_b = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    n_m = _axis_size(mesh, "model")

    def maybe_b(sz):
        return baxes if baxes and sz % n_b == 0 else None

    def maybe_m(sz):
        return "model" if "model" in mesh.axis_names and sz % n_m == 0 \
            else None

    def shard(x, name):
        s = x.shape
        if name == "act_resid" and x.ndim == 3:
            spec = P(maybe_b(s[0]), None, None)
        elif name == "act_heads" and x.ndim == 4:
            spec = P(maybe_b(s[0]), None, maybe_m(s[2]), None)
        elif name == "act_ffn" and x.ndim == 3:
            spec = P(maybe_b(s[0]), None, maybe_m(s[2]))
        elif name == "attn_logits" and x.ndim == 5:
            spec = P(maybe_b(s[0]), maybe_m(s[1]), None, None, None)
        elif name == "attn_logits4" and x.ndim == 4:
            # kv-replicated GQA: [B, H, Sq, Sk] shards fully on q heads
            spec = P(maybe_b(s[0]), maybe_m(s[1]), None, None)
        elif name == "logits" and x.ndim == 3:
            spec = P(maybe_b(s[0]), None, maybe_m(s[2]))
        elif name == "logits_last" and x.ndim == 2:
            spec = P(maybe_b(s[0]), maybe_m(s[1]))
        elif name == "moe_dispatch" and x.ndim == 3:
            spec = P(maybe_m(s[0]), None, None)       # experts on model
        elif name == "moe_ffn" and x.ndim == 3:
            spec = P(maybe_m(s[0]), None, None)
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    shard.model_size = n_m      # lets layers pick kv-replicated GQA
    return shard


def cache_pspecs(cache: PyTree, mesh: Mesh, batch: int) -> PyTree:
    """Decode-cache specs: batch over pod+data when divisible; KV heads /
    rwkv heads over model when divisible; latent dims replicated."""
    baxes = batch_axes(mesh)
    n_b = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    n_m = _axis_size(mesh, "model")

    def spec_for(path, leaf):
        name = _leaf_name(path)
        if name == "length":
            return P(*([None] * leaf.ndim))
        # find the batch dim: first dim equal to `batch` (after optional
        # stacked period axis)
        dims: list[Any] = [None] * leaf.ndim
        for i, sz in enumerate(leaf.shape):
            if sz == batch and batch % n_b == 0 and baxes:
                dims[i] = baxes
                break
        if name in ("k", "v") and leaf.ndim >= 3 \
                and "model" in mesh.axis_names:
            if leaf.shape[-2] % n_m == 0:
                dims[-2] = "model"              # KV heads on model
            elif leaf.shape[-3] % n_m == 0:
                # split-KV (flash-decoding style): when the kv-head count
                # does not divide the model axis (GQA kv=8 under 16), shard
                # the SEQUENCE dim instead — without this, 32k x batch
                # caches replicate across model and overflow HBM
                # (EXPERIMENTS.md Perf iteration 7)
                dims[-3] = "model"
        if name == "latent" and leaf.ndim >= 2 \
                and "model" in mesh.axis_names \
                and leaf.shape[-2] % n_m == 0:
            dims[-2] = "model"                  # MLA latent: seq on model
        if name == "k_rope" and leaf.ndim >= 3 \
                and "model" in mesh.axis_names \
                and leaf.shape[-3] % n_m == 0:
            dims[-3] = "model"
        if name == "state" and leaf.ndim >= 3:      # rwkv [.., H, hd, hd]
            if leaf.shape[-3] % n_m == 0 and "model" in mesh.axis_names:
                dims[-3] = "model"
        return P(*dims)

    flat, _ = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache),
        [spec_for(p, l) for p, l in flat])
