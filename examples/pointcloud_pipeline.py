"""Point-cloud processing pipeline: KNN normal estimation on a KITTI-like
LiDAR frame — the perception workload class (PCL) the paper's KNN serves.

For every point: find K nearest neighbors, fit a local plane (PCA of the
neighborhood covariance), output the normal. Runs the full RTNN pipeline
(schedule + partition + bundle) and cross-checks a sample against brute
force.

  PYTHONPATH=src python examples/pointcloud_pipeline.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NeighborSearch, SearchOpts, SearchParams
from repro.data.pointclouds import kitti_like_cloud
from repro.kernels.ref import brute_force_search

K = 16
R = 0.03


@jax.jit
def estimate_normals(points, nbr_idx):
    valid = (nbr_idx >= 0)[..., None]
    nbrs = points[jnp.clip(nbr_idx, 0)]                     # [N, K, 3]
    cnt = jnp.maximum(valid.sum(axis=1), 1)
    mean = jnp.sum(jnp.where(valid, nbrs, 0), axis=1) / cnt
    centered = jnp.where(valid, nbrs - mean[:, None], 0)
    cov = jnp.einsum("nki,nkj->nij", centered, centered) / cnt[..., None]
    # normal = eigenvector of the smallest eigenvalue
    w, v = jnp.linalg.eigh(cov)
    return v[..., 0]


def main():
    pts = kitti_like_cloud(60_000, seed=3)
    t0 = time.perf_counter()
    ns = NeighborSearch(pts, SearchParams(radius=R, k=K))
    res = ns.query(pts)
    t_search = time.perf_counter() - t0
    normals = estimate_normals(jnp.asarray(pts), res.indices)
    print(f"searched {len(pts)} points in {t_search:.2f}s "
          f"({t_search / len(pts) * 1e6:.1f} us/query, "
          f"{ns.report.num_partitions} partitions)")

    # verify sample vs brute force
    oi, od, oc = brute_force_search(jnp.asarray(pts), jnp.asarray(pts[:200]),
                                    R, K)
    got = np.asarray(res.distances2[:200])
    want = np.asarray(od)
    match = np.allclose(np.where(np.isinf(got), -1, got),
                        np.where(np.isinf(want), -1, want), atol=1e-5)
    print("sample oracle match:", match)
    # normals on a flat slab should be mostly vertical
    vertical = np.abs(np.asarray(normals)[:, 2]) > 0.9
    print(f"vertical normals: {vertical.mean() * 100:.0f}% "
          "(KITTI-like ground slab)")
    assert match


if __name__ == "__main__":
    main()
