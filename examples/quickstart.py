"""Quickstart: RTNN-style neighbor search in three lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import NeighborSearch, SearchOpts, SearchParams

rng = np.random.default_rng(0)
points = rng.random((50_000, 3)).astype(np.float32)   # your point cloud
queries = rng.random((5_000, 3)).astype(np.float32)   # where to search

# K-nearest-neighbor search, bounded by a radius (the paper's unified
# (r, K) interface, section 2.1)
searcher = NeighborSearch(points, SearchParams(radius=0.05, k=8))
result = searcher.query(queries)

print("indices   ", result.indices.shape, "(-1 padded)")
print("distances2", result.distances2.shape, "(inf padded)")
print("counts    ", np.asarray(result.counts)[:10])
print(f"partitions={searcher.report.num_partitions} "
      f"bundles={len(searcher.report.bundles)} "
      f"t_opt={searcher.report.t_opt * 1e3:.1f}ms "
      f"t_search={searcher.report.t_search * 1e3:.1f}ms")

# fixed-radius ("range") search with the same structure: first-K within r
range_result = NeighborSearch(
    points, SearchParams(radius=0.05, k=16, mode="range"),
    SearchOpts(bundle=True)).query(queries)
print("range counts", np.asarray(range_result.counts)[:10])
