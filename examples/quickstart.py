"""Quickstart: RTNN-style neighbor search, functional-first.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.api import SearchParams

rng = np.random.default_rng(0)
points = rng.random((50_000, 3)).astype(np.float32)   # your point cloud
queries = rng.random((5_000, 3)).astype(np.float32)   # where to search

# K-nearest-neighbor search, bounded by a radius (the paper's unified
# (r, K) interface, section 2.1). The index is a pytree; query is a pure
# function — jit it, vmap it, close over it in your own step function.
index = api.build_index(points, SearchParams(radius=0.05, k=8))
result = jax.jit(api.query)(index, queries)

print("indices   ", result.indices.shape, "(-1 padded)")
print("distances2", result.distances2.shape, "(inf padded)")
print("counts    ", np.asarray(result.counts)[:10])

# moving points? update_index re-bins into the frozen spec, on device
moved = np.clip(points + rng.normal(0, 1e-3, points.shape),
                0, 1).astype(np.float32)
index2, stats = api.update_index(index, moved)
print("update    ", "max_disp2=%.2e" % float(stats.max_disp2),
      "oob=%d" % int(stats.oob))

# batch of independent same-spec scenes == vmap (multi-scene batching)
scenes = jnp.stack([jnp.asarray(points), jnp.asarray(moved)])
batch_q = jnp.stack([jnp.asarray(queries)] * 2)
stacked = jax.vmap(
    lambda p: api.build_index(p, SearchParams(radius=0.05, k=8),
                              spec=index.spec))(scenes)
batch = jax.jit(jax.vmap(api.query))(stacked, batch_q)
print("batched   ", batch.indices.shape, "(2 scenes, one compiled program)")

# the eager class surface is a shim over the same core, with the
# host-planned executor (cost-model bundling) as its optimizing path
from repro.core import NeighborSearch, SearchOpts

searcher = NeighborSearch(points, SearchParams(radius=0.05, k=8))
res_eager = searcher.query(queries)
assert np.array_equal(np.asarray(res_eager.counts),
                      np.asarray(result.counts))
print(f"eager     partitions={searcher.report.num_partitions} "
      f"bundles={len(searcher.report.bundles)} "
      f"t_search={searcher.report.t_search * 1e3:.1f}ms")

# fixed-radius ("range") search with the same structure: first-K within r
range_result = NeighborSearch(
    points, SearchParams(radius=0.05, k=16, mode="range"),
    SearchOpts(bundle=True)).query(queries)
print("range counts", np.asarray(range_result.counts)[:10])
