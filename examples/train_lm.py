"""End-to-end LM training driver: ~110M-parameter model, a few hundred
steps, with checkpointing + fault-tolerant resume (deliverable b).

Thin wrapper over the production launcher so the example exercises the
same code path a fleet run would:

  PYTHONPATH=src python examples/train_lm.py          # quick (25 steps)
  PYTHONPATH=src python examples/train_lm.py --full   # few hundred steps
"""
import subprocess
import sys
import os

full = "--full" in sys.argv
steps = "300" if full else "25"
env = {**os.environ,
       "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}
cmd = [sys.executable, "-m", "repro.launch.train",
       "--arch", "lm-100m", "--steps", steps,
       "--batch", "8", "--seq", "256", "--n-micro", "2",
       "--ckpt-dir", "/tmp/rtnn_lm100m_ckpt",
       "--save-every", "10", "--log-every", "5"]
print("+", " ".join(cmd[1:]))
raise SystemExit(subprocess.call(cmd, env=env))
