"""End-to-end driver: a minimal SPH-style fluid step loop built on the
neighbor-search core — the application class (SPlisHSPlasH / cuNSearch)
the paper's range search serves.

Each step: (1) rebuild the structure over moved particles, (2) range
search around every particle, (3) density + pressure-force kernel sums
over the returned neighbor lists, (4) symplectic Euler integration.

  PYTHONPATH=src python examples/sph_fluid.py --particles 8000 --steps 5
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NeighborSearch, SearchOpts, SearchParams

H = 0.06            # smoothing radius
K_MAX = 32          # bounded neighbor count (the paper's K)
REST_DENSITY = 600.0
STIFFNESS = 200.0
DT = 4e-4
GRAVITY = jnp.asarray([0.0, 0.0, -9.8])


@jax.jit
def sph_forces(pos, vel, nbr_idx, nbr_d2):
    """Poly6 density + spiky pressure-gradient forces over the fixed-K
    neighbor lists returned by the search."""
    valid = nbr_idx >= 0
    safe = jnp.clip(nbr_idx, 0)
    d2 = jnp.where(valid, nbr_d2, H * H)
    w = jnp.maximum(H * H - d2, 0.0) ** 3                    # poly6 core
    density = jnp.sum(jnp.where(valid, w, 0.0), axis=1) * 315.0 / (
        64.0 * jnp.pi * H**9) + 1e-6
    pressure = STIFFNESS * jnp.maximum(density - REST_DENSITY, 0.0)

    d = jnp.sqrt(jnp.maximum(d2, 1e-12))
    dirs = (pos[:, None, :] - pos[safe]) / d[..., None]
    spiky = (H - d) ** 2 * 45.0 / (jnp.pi * H**6)
    p_i = pressure[:, None]
    p_j = pressure[safe]
    rho_j = density[safe]
    f = dirs * (spiky * (p_i + p_j) / (2.0 * rho_j))[..., None]
    f = jnp.sum(jnp.where(valid[..., None], f, 0.0), axis=1)
    return f / density[:, None] + GRAVITY, density


def step(pos, vel):
    ns = NeighborSearch(np.asarray(pos),
                        SearchParams(radius=H, k=K_MAX, mode="range"),
                        SearchOpts())
    res = ns.query(np.asarray(pos))
    acc, density = sph_forces(jnp.asarray(pos), vel, res.indices,
                              res.distances2)
    vel = vel + DT * acc
    pos = pos + DT * vel
    # keep particles in the box (reflective walls)
    pos = jnp.clip(pos, 0.0, 1.0)
    vel = jnp.where((pos <= 0.0) | (pos >= 1.0), -0.5 * vel, vel)
    return pos, vel, float(density.mean()), ns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=8000)
    ap.add_argument("--steps", type=int, default=5)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    pos = jnp.asarray(rng.random((args.particles, 3), np.float32) *
                      [0.4, 0.4, 0.8])          # dam-break column
    vel = jnp.zeros_like(pos)
    for s in range(args.steps):
        t0 = time.perf_counter()
        pos, vel, rho, ns = step(pos, vel)
        dt = time.perf_counter() - t0
        print(f"step {s}: mean_density={rho:9.1f} "
              f"partitions={ns.report.num_partitions} "
              f"launches={ns.report.launches} "
              f"syncs={ns.report.host_syncs} "
              f"wall={dt:.2f}s")
    assert np.isfinite(np.asarray(pos)).all()
    print("ok")


if __name__ == "__main__":
    main()
