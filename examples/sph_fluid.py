"""End-to-end driver: a minimal SPH-style fluid step loop built on the
neighbor-search core — the application class (SPlisHSPlasH / cuNSearch)
the paper's range search serves.

Default path is the dynamic-scene subsystem (DESIGN.md section 7): ONE
persistent ``SimulationSession`` owns a frozen grid across the whole run,
each step re-bins the moved particles device-resident and replays the
cached schedule/partition plan while displacements stay small. Positions
never leave the device. ``--rebuild`` keeps the legacy path for A/B: a
fresh ``NeighborSearch`` per frame (host spec planning, full rebuild, cold
plan caches — what the session amortizes away).

Each step: (1) update structure over moved particles, (2) range search
around every particle (self-query), (3) density + pressure-force kernel
sums over the returned neighbor lists, (4) symplectic Euler integration.

  PYTHONPATH=src python examples/sph_fluid.py --particles 8000 --steps 5
  PYTHONPATH=src python examples/sph_fluid.py --rebuild   # legacy A/B
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (NeighborSearch, SearchOpts, SearchParams,
                        SimulationSession)

H = 0.06            # smoothing radius
K_MAX = 32          # bounded neighbor count (the paper's K)
REST_DENSITY = 600.0
STIFFNESS = 200.0
DT = 4e-4
GRAVITY = jnp.asarray([0.0, 0.0, -9.8])


@jax.jit
def sph_forces(pos, vel, nbr_idx, nbr_d2):
    """Poly6 density + spiky pressure-gradient forces over the fixed-K
    neighbor lists returned by the search."""
    valid = nbr_idx >= 0
    safe = jnp.clip(nbr_idx, 0)
    d2 = jnp.where(valid, nbr_d2, H * H)
    w = jnp.maximum(H * H - d2, 0.0) ** 3                    # poly6 core
    density = jnp.sum(jnp.where(valid, w, 0.0), axis=1) * 315.0 / (
        64.0 * jnp.pi * H**9) + 1e-6
    pressure = STIFFNESS * jnp.maximum(density - REST_DENSITY, 0.0)

    d = jnp.sqrt(jnp.maximum(d2, 1e-12))
    dirs = (pos[:, None, :] - pos[safe]) / d[..., None]
    spiky = (H - d) ** 2 * 45.0 / (jnp.pi * H**6)
    p_i = pressure[:, None]
    p_j = pressure[safe]
    rho_j = density[safe]
    f = dirs * (spiky * (p_i + p_j) / (2.0 * rho_j))[..., None]
    f = jnp.sum(jnp.where(valid[..., None], f, 0.0), axis=1)
    return f / density[:, None] + GRAVITY, density


@jax.jit
def integrate(pos, vel, acc):
    """Symplectic Euler + reflective box walls, all on device."""
    vel = vel + DT * acc
    pos = pos + DT * vel
    pos = jnp.clip(pos, 0.0, 1.0)
    vel = jnp.where((pos <= 0.0) | (pos >= 1.0), -0.5 * vel, vel)
    return pos, vel


def step_rebuild(pos, vel):
    """Legacy per-frame teardown/rebuild (pre-session behavior)."""
    ns = NeighborSearch(np.asarray(pos),
                        SearchParams(radius=H, k=K_MAX, mode="range"),
                        SearchOpts())
    t0 = time.perf_counter()
    res = ns.query(np.asarray(pos))
    t_search = time.perf_counter() - t0
    t0 = time.perf_counter()
    acc, density = sph_forces(jnp.asarray(pos), vel, res.indices,
                              res.distances2)
    pos, vel = integrate(jnp.asarray(pos), vel, acc)
    jax.block_until_ready(pos)
    t_phys = time.perf_counter() - t0
    split = dict(update=0.0, plan=ns.report.t_opt, search=t_search,
                 physics=t_phys)
    info = (f"partitions={ns.report.num_partitions} "
            f"launches={ns.report.launches} syncs={ns.report.host_syncs}")
    return pos, vel, float(density.mean()), split, info


def step_session(sess, pos, vel):
    """Session path: incremental update + cached-plan replay, self-query."""
    res = sess.step(pos)
    r = sess.report
    t0 = time.perf_counter()
    acc, density = sph_forces(pos, vel, res.indices, res.distances2)
    pos, vel = integrate(pos, vel, acc)
    jax.block_until_ready(pos)
    t_phys = time.perf_counter() - t0
    split = dict(update=r.t_update, plan=r.t_plan, search=r.t_search,
                 physics=t_phys)
    info = (f"fast={int(r.fast)} replan={int(r.replanned)} "
            f"respec={int(r.respecced)} disp={r.max_disp:.4f}")
    return pos, vel, float(density.mean()), split, info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=8000)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--rebuild", action="store_true",
                    help="legacy rebuild-per-frame path (A/B baseline)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    pos = jnp.asarray(rng.random((args.particles, 3), np.float32) *
                      [0.4, 0.4, 0.8])          # dam-break column
    vel = jnp.zeros_like(pos)
    sess = None
    if not args.rebuild:
        sess = SimulationSession(
            pos, SearchParams(radius=H, k=K_MAX, mode="range"),
            SearchOpts())
    for s in range(args.steps):
        t0 = time.perf_counter()
        if args.rebuild:
            pos, vel, rho, split, info = step_rebuild(pos, vel)
        else:
            pos, vel, rho, split, info = step_session(sess, pos, vel)
        dt = time.perf_counter() - t0
        print(f"step {s}: mean_density={rho:9.1f} wall={dt:.2f}s "
              f"(update={split['update']:.3f} plan={split['plan']:.3f} "
              f"search={split['search']:.3f} "
              f"physics={split['physics']:.3f}) {info}")
    if sess is not None:
        st = sess.stats()
        print(f"session: {st['steps']} steps, {st.get('fast_steps', 0)} "
              f"fast, {st.get('replans', 0)} replans, "
              f"{st.get('respecs', 0)} respecs")
    assert np.isfinite(np.asarray(pos)).all()
    print("ok")


if __name__ == "__main__":
    main()
